"""Pallas kernel sweeps: interpret-mode kernel vs pure-jnp oracle across
shapes and dtypes, plus gradient flow through the custom_vjp wrappers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX smoke: outside the tier-1 budget

from repro.kernels import ops, ref

rng = np.random.default_rng(42)


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 3e-5


FA_CASES = [
    # b, hq, hkv, sq, sk, d, causal, window, q_offset
    (2, 4, 2, 128, 128, 64, True, None, 0),      # GQA causal
    (1, 8, 8, 256, 256, 32, True, 64, 0),        # MHA sliding window
    (1, 4, 1, 1, 256, 64, True, None, 255),      # decode (q_len=1)
    (2, 4, 2, 200, 200, 64, True, None, 0),      # ragged tails
    (1, 2, 2, 128, 128, 64, False, None, 0),     # bidirectional (encoder)
    (1, 6, 3, 96, 96, 48, True, 32, 0),          # window + GQA + ragged
    (1, 4, 2, 64, 192, 32, True, None, 128),     # chunked prefill offset
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_matches_oracle(case, dtype):
    b, hq, hkv, sq, sk, d, causal, window, q_offset = case
    q = jnp.asarray(rng.standard_normal((b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, sk, d)), dtype)
    want = ref.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("block", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shape_invariance(block):
    bq, bk = block
    q = jnp.asarray(rng.standard_normal((1, 2, 160, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 160, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 160, 32)), jnp.float32)
    from repro.kernels.flash_attention import flash_attention_pallas
    want = ref.flash_attention(q, k, v)
    got = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


SSD_CASES = [
    # b, s, h, p, n, chunk
    (2, 256, 2, 32, 16, 64),
    (1, 128, 4, 64, 32, 32),
    (1, 64, 1, 16, 8, 64),       # chunk clamps to seq
    (1, 512, 2, 32, 128, 128),   # large state
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_scan_matches_oracle(case, dtype):
    b, s, h, p, n, chunk = case
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), dtype)
    loga = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))) * 0.1,
                       jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, dtype)
    cc = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3, dtype)
    wy, wh = ref.ssd_scan(x, loga, bb, cc)
    gy, gh = ops.ssd_scan(x, loga, bb, cc, chunk=chunk,
                          impl="pallas_interpret")
    tol = 6e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(gy, np.float32),
                               np.asarray(wy, np.float32),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(wh),
                               atol=tol, rtol=tol)


def test_ssd_chunk_size_invariance():
    x = jnp.asarray(rng.standard_normal((1, 240, 2, 16)), jnp.float32)
    loga = jnp.asarray(-np.abs(rng.standard_normal((1, 240, 2))) * 0.05,
                       jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 240, 2, 8)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((1, 240, 2, 8)) * 0.3, jnp.float32)
    outs = []
    for chunk in (16, 48, 240):
        y, h = ops.ssd_scan(x, loga, b, c, chunk=chunk,
                            impl="pallas_interpret")
        outs.append((np.asarray(y), np.asarray(h)))
    for y, h in outs[1:]:
        np.testing.assert_allclose(y, outs[0][0], atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(h, outs[0][1], atol=1e-4, rtol=1e-4)


def test_flash_attention_grads_match_reference():
    q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)

    def f_pallas(q, k, v):
        return (ops.flash_attention(q, k, v,
                                    impl="pallas_interpret") ** 2).sum()

    def f_ref(q, k, v):
        return (ref.flash_attention(q, k, v) ** 2).sum()

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_ssd_grads_flow():
    x = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    loga = jnp.asarray(-np.abs(rng.standard_normal((1, 64, 2))) * 0.1,
                       jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 64, 2, 8)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((1, 64, 2, 8)) * 0.3, jnp.float32)

    def f(x, loga, b, c):
        y, _ = ops.ssd_scan(x, loga, b, c, chunk=32,
                            impl="pallas_interpret")
        return (y ** 2).sum()

    grads = jax.grad(f, argnums=(0, 1, 2, 3))(x, loga, b, c)

    def fr(x, loga, b, c):
        y, _ = ref.ssd_scan(x, loga, b, c)
        return (y ** 2).sum()

    grefs = jax.grad(fr, argnums=(0, 1, 2, 3))(x, loga, b, c)
    for a, b_ in zip(grads, grefs):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-4, rtol=1e-4)


def test_flash_chunked_matches_naive():
    for (b, hq, hkv, sq, sk, causal, window, off) in [
            (1, 4, 2, 96, 96, True, None, 0),
            (2, 2, 2, 64, 64, True, 24, 0),
            (1, 4, 1, 1, 200, True, None, 199),
            (1, 2, 2, 80, 80, False, None, 0)]:
        q = jnp.asarray(rng.standard_normal((b, hq, sq, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, hkv, sk, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, hkv, sk, 32)), jnp.float32)
        want = ref.flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=off)
        got = ref.flash_attention_chunked(q, k, v, causal=causal,
                                          window=window, q_offset=off,
                                          block_k=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5, rtol=3e-5)


def test_ssd_chunked_matches_naive():
    for (b, s, h, p, n, chunk) in [(2, 128, 2, 16, 8, 32),
                                   (1, 96, 3, 8, 4, 96),
                                   (1, 256, 1, 32, 16, 64)]:
        x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
        loga = jnp.asarray(-np.abs(rng.standard_normal((b, s, h))) * 0.1,
                           jnp.float32)
        bb = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3,
                         jnp.float32)
        cc = jnp.asarray(rng.standard_normal((b, s, h, n)) * 0.3,
                         jnp.float32)
        wy, wh = ref.ssd_scan(x, loga, bb, cc)
        gy, gh = ref.ssd_scan_chunked(x, loga, bb, cc, chunk=chunk)
        np.testing.assert_allclose(np.asarray(gy), np.asarray(wy),
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(np.asarray(gh), np.asarray(wh),
                                   atol=2e-4, rtol=2e-4)
