"""Sharding resolver: divisibility fallbacks, ZeRO-1, cache/batch specs.
Runs on a 1x1 mesh (shape logic only — mesh extents are parameterized)."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel import (batch_pspec, cache_pspec, default_rules,
                            pspec_for)
from repro.parallel.sharding import zero1_pspec


class FakeMesh:
    """Duck-typed mesh: only .shape and .axis_names are consulted."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisible_dims_shard():
    rules = default_rules(MESH)
    assert pspec_for(("embed", "ff"), (1024, 3072), MESH, rules) \
        == P(None, "model")
    assert pspec_for(("vocab", "embed"), (151936, 1024), MESH, rules) \
        == P("model")


def test_non_divisible_dims_replicate():
    rules = default_rules(MESH)
    # whisper: 6 heads * 64 = 384 -> 384 % 16 == 0 shards; vocab 51865 not
    assert pspec_for(("vocab", "embed"), (51865, 384), MESH, rules) == P()
    # 92553 % 16 != 0 -> replicated (internvl2 pre-padding)
    assert pspec_for(("vocab", "embed"), (92553, 2048), MESH, rules) == P()


def test_multi_pod_batch_axes():
    rules = default_rules(MESH_MP)
    assert rules.batch_axes == ("pod", "data")
    assert batch_pspec((256, 4096), MESH_MP, rules) == P(("pod", "data"))
    # batch 1 cannot shard
    assert batch_pspec((1, 4096), MESH_MP, rules) == P()


def test_cache_pspec_falls_back_to_seq():
    rules = default_rules(MESH)
    # decode_32k: batch over data AND sequence over model (2D; §Perf#2)
    assert cache_pspec((4, 128, 8, 32768, 128), MESH, rules) \
        == P(None, "data", None, "model")
    # long_500k: batch 1 -> the sequence dim takes every axis
    assert cache_pspec((4, 1, 8, 524288, 128), MESH, rules) \
        == P(None, None, None, ("data", "model"))
    # non-divisible seq with divisible batch: batch-only
    assert cache_pspec((4, 128, 8, 1000, 128), MESH, rules) \
        == P(None, "data")


def test_zero1_adds_data_axis():
    rules = default_rules(MESH)
    # param sharding: ff on model only
    assert pspec_for(("embed", "ff"), (1024, 3072), MESH, rules) \
        == P(None, "model")
    # zero1: first replicated divisible dim picks up data
    assert zero1_pspec(("embed", "ff"), (1024, 3072), MESH, rules) \
        == P("data", "model")


def test_zero1_skips_non_divisible():
    rules = default_rules(MESH)
    assert zero1_pspec(("ff",), (10,), MESH, rules) == P()  # 10 % 16 != 0
    # multi-pod: data axes are (pod, data) = 32-way
    rules_mp = default_rules(MESH_MP)
    assert zero1_pspec(("embed", "ff"), (1024, 3072), MESH_MP, rules_mp) \
        == P(("pod", "data"), "model")


def test_expert_partition_mode():
    rules = default_rules(MESH, expert_partition="expert")
    # olmoe: 64 experts % 16 == 0 -> EP on the expert dim
    assert pspec_for(("expert", "embed", "expert_ff"), (64, 2048, 1024),
                     MESH, rules) == P("model")
    # qwen2-moe: 60 % 16 != 0 -> expert dim replicates under EP mode
    assert pspec_for(("expert", "embed", "expert_ff"), (60, 2048, 1408),
                     MESH, rules) == P()
