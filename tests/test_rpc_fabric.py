"""Typed RPC dispatch fabric: registry completeness, unknown-method errors,
declared payload defaults, and per-method stats accounting."""

import pytest

from repro.core import SimTimeout, UnknownRpcError
from conftest import make_cluster, make_fs

EXPECTED_METHODS = {
    # read path (server façade)
    "rpc_getattr", "rpc_lookup", "rpc_readdir", "rpc_read_chunk",
    "rpc_nodelist", "rpc_stage_write",
    # participant
    "rpc_prepare", "rpc_commit", "rpc_abort",
    # coordinator
    "coord_create", "coord_load_dir", "coord_flush_write", "coord_unlink",
    "coord_rename", "coord_truncate",
    # persist
    "coord_persist", "rpc_upload_part", "rpc_clear_chunk_dirty",
    # migration
    "rpc_set_read_only", "rpc_migrate_recv_meta", "rpc_migrate_recv_chunk",
}


def test_registry_contains_all_wire_methods(workdir):
    cl = make_cluster(workdir, n=2)
    for nm in cl.node_list():
        assert set(cl.router.registered_methods(nm)) == EXPECTED_METHODS
    cl.close()


def test_unknown_method_raises_not_getattr(workdir):
    cl = make_cluster(workdir, n=2)
    nm = cl.node_list()[0]
    with pytest.raises(UnknownRpcError):
        cl.router.rpc(None, nm, "coord_execute", cl.clock.now)  # not wired
    with pytest.raises(UnknownRpcError):
        cl.router.rpc(None, nm, "restart", cl.clock.now)  # lifecycle, not RPC
    # a typo'd name on a *crashed* node is still a programming error, not a
    # timeout — and it must not leave a phantom entry in the method stats
    other = cl.node_list()[1]
    cl.crash_node(other)
    with pytest.raises(UnknownRpcError):
        cl.router.rpc(None, other, "rpc_stage_writ", cl.clock.now)
    assert "rpc_stage_writ" not in cl.router.method_stats
    cl.close()


def test_declared_payload_sizes_are_defaults(workdir):
    cl = make_cluster(workdir, n=2)
    nm = cl.node_list()[0]
    spec = cl.router.handlers[nm]["rpc_nodelist"][1]
    before = cl.router.rpc_bytes
    _, t = cl.router.rpc(None, nm, "rpc_nodelist", cl.clock.now)
    assert cl.router.rpc_bytes - before == spec.request_bytes + spec.reply_bytes
    # explicit sizes still win over the declared defaults
    before = cl.router.rpc_bytes
    _, t = cl.router.rpc(None, nm, "rpc_nodelist", t,
                         nbytes_out=1000, nbytes_in=2000)
    assert cl.router.rpc_bytes - before == 3000
    cl.close()


def test_per_method_stats_recorded(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    fs.write_file("/b/s.bin", b"x" * 1024)
    assert fs.read_file("/b/s.bin") == b"x" * 1024

    stats = cl.rpc_stats()
    for method in ("rpc_stage_write", "coord_flush_write", "coord_create",
                   "rpc_getattr", "rpc_read_chunk"):
        assert stats[method]["calls"] >= 1, method
        assert stats[method]["bytes"] > 0, method
        assert stats[method]["vtime"] >= 0.0, method
    # the same counters land in the destination server's stats dict
    per_server = [s.stats.get("rpc.rpc_stage_write.calls", 0)
                  for s in cl.servers.values()]
    assert sum(per_server) == stats["rpc_stage_write"]["calls"]
    cl.close()


def test_handler_errors_counted_separately(workdir):
    """A handler that raises must not count as a completed call — the
    per-server/global `calls` invariant holds across failed dispatches."""
    from repro.core import FSError
    cl = make_cluster(workdir, n=2)
    nm = cl.node_list()[0]
    with pytest.raises(FSError):   # ENOENT from rpc_getattr
        cl.router.rpc(None, nm, "rpc_getattr", cl.clock.now, ino=999999)
    ms = cl.router.method_stats["rpc_getattr"]
    assert ms["errors"] == 1 and ms["calls"] == 0
    assert cl.servers[nm].stats.get("rpc.rpc_getattr.calls", 0) == 0
    cl.close()


def test_timeouts_counted_per_method(workdir):
    cl = make_cluster(workdir, n=2)
    victim = cl.node_list()[1]
    cl.crash_node(victim)
    with pytest.raises(SimTimeout):
        cl.router.rpc(None, victim, "rpc_nodelist", cl.clock.now)
    assert cl.router.method_stats["rpc_nodelist"]["timeouts"] == 1
    assert cl.router.method_stats["rpc_nodelist"]["calls"] == 0
    cl.close()


def test_unregister_removes_dispatch_entries(workdir):
    cl = make_cluster(workdir, n=2)
    victim = cl.node_list()[1]
    cl.router.unregister(victim)
    assert cl.router.registered_methods(victim) == []
    with pytest.raises(SimTimeout):   # unreachable before dispatch lookup
        cl.router.rpc(None, victim, "rpc_nodelist", cl.clock.now)
    cl.close()
