"""Per-architecture smoke: reduced config, one forward/train step on CPU,
asserting output shapes and no NaNs (the brief's required smoke)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # JAX smoke: outside the tier-1 budget

from repro import configs
from repro.models import build_model
from repro.models.lm import frontend_dim
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_state_init

B, S = 2, 32


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend is not None:
        nf = cfg.enc_seq if cfg.family == "audio" else cfg.n_frontend_tokens
        batch["frontend"] = jax.random.normal(
            key, (B, nf, frontend_dim(cfg)), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    model = build_model(cfg)
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=S)
    step = jax.jit(make_train_step(model, AdamWConfig(warmup_steps=1,
                                                      total_steps=10)))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state.step) == 1
    # params actually changed and stayed finite
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree.leaves(moved)) > 0
    for leaf in jax.tree.leaves(new_state.params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_step_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), max_seq=S)
    cache = model.init_cache(B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode)(params, tok, cache, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # cache tree structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m",
                                  "h2o-danube-3-4b", "jamba-v0.1-52b"])
def test_decode_matches_prefill_next_token(arch):
    """Greedy next-token from step-by-step decode must agree with a full
    forward pass (cache correctness, incl. ring buffers and SSM state)."""
    cfg = configs.get_reduced(arch)
    if cfg.moe is not None:
        # decode is dropless; make prefill effectively dropless too so the
        # equivalence is exact (capacity drops are a train-time trade-off)
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                capacity_factor=8.0))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), max_seq=S)
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab)
    # full forward: logits at the last position
    full = model.prefill(params, {"tokens": toks})
    # token-by-token decode
    cache = model.init_cache(1, S)
    logits = None
    for t in range(toks.shape[1]):
        logits, cache = model.decode(params, toks[:, t:t + 1], cache,
                                     jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits[:, -1], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_match_public_sizes():
    """Analytic parameter counts land on the published model sizes."""
    expect = {
        "qwen3-0.6b": (0.5e9, 0.8e9),
        "qwen2.5-14b": (13.5e9, 15.5e9),
        "granite-8b": (7.5e9, 9e9),
        "h2o-danube-3-4b": (3.5e9, 4.5e9),
        "qwen2-moe-a2.7b": (13e9, 15.5e9),     # 14.3B total
        "olmoe-1b-7b": (6.5e9, 7.5e9),
        "jamba-v0.1-52b": (50e9, 53e9),
        "internvl2-2b": (1.7e9, 2.2e9),        # LLM backbone
        "whisper-tiny": (0.03e9, 0.06e9),
        "mamba2-370m": (0.3e9, 0.45e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active-parameter counts
    assert 2.2e9 <= configs.get_config(
        "qwen2-moe-a2.7b").active_param_count() <= 3.2e9
    assert 1.0e9 <= configs.get_config(
        "olmoe-1b-7b").active_param_count() <= 1.6e9
    assert 11e9 <= configs.get_config(
        "jamba-v0.1-52b").active_param_count() <= 13e9
