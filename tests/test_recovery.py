"""Failure recovery at Fig. 8's black dots: crashes around the persisting
transaction (MPU), replay idempotence, and injected COS failures."""

import pytest

from repro.core import CosError
from repro.core.net import SimCrash
from conftest import CHUNK, make_cluster, make_fs


def _put_big_dirty(fs, path, n):
    import numpy as np
    data = bytes(np.random.default_rng(5).integers(0, 256, size=n,
                                                   dtype=np.uint8))
    fs.write_file(path, data)
    return data


def test_mpu_begin_failure_aborts_cleanly(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _put_big_dirty(fs, "/b/m.bin", 3 * CHUNK)
    cl.cos.fail_next("mpu_begin")
    fh = fs.open("/b/m.bin", "r+")
    fs.fsync(fh)     # outcome abort is swallowed into retry by the client
    fs.close(fh)
    # content still consistent, still reachable, eventually uploads
    assert fs.read_file("/b/m.bin") == data
    cl.drain_dirty()
    assert cl.cos.get_object("b", "m.bin")[0] == data
    assert cl.cos.outstanding_mpus() == []
    cl.close()


def test_mpu_add_failure_aborts_upload(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _put_big_dirty(fs, "/b/m2.bin", 3 * CHUNK)
    cl.cos.fail_next("mpu_add")
    fh = fs.open("/b/m2.bin", "r+")
    fs.fsync(fh)
    fs.close(fh)
    cl.drain_dirty()
    assert cl.cos.get_object("b", "m2.bin")[0] == data
    assert cl.cos.outstanding_mpus() == []
    cl.close()


def test_crash_after_mpu_commit_before_log(workdir):
    """Fig. 8 note: 'a failure between the MPU Commit and recording the log
    may result in uploading the same content twice' — re-upload must be
    idempotent, never corrupt."""
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _put_big_dirty(fs, "/b/m3.bin", 2 * CHUNK + 50)
    meta_owner = None
    for nm, s in cl.servers.items():
        for ino in s.metas.dirty_inos():
            m = s.metas.get(ino)
            if m and m.cos_key == "m3.bin":
                meta_owner = s
    assert meta_owner is not None
    meta_owner.arm_crash("persist_after_mpu_commit")
    fh = fs.open("/b/m3.bin", "r+")
    with pytest.raises(Exception):
        fs.fsync(fh)
    # server crashed mid-persist; restart replays the WAL
    cl.restart_node(meta_owner.node_id)
    fs.client._pull_node_list()
    fs.fsync(fh)      # retry completes (possibly re-uploading — idempotent)
    fs.close(fh)
    assert cl.cos.get_object("b", "m3.bin")[0] == data
    cl.close()


def test_crash_during_put_fast_path(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _put_big_dirty(fs, "/b/small.bin", CHUNK // 2)
    victim = None
    for nm, s in cl.servers.items():
        for ino in s.metas.dirty_inos():
            m = s.metas.get(ino)
            if m and m.cos_key == "small.bin":
                victim = s
    if victim is None:
        pytest.skip("meta owner not local to any dirty list")
    victim.arm_crash("persist_after_put")
    fh = fs.open("/b/small.bin", "r+")
    try:
        fs.fsync(fh)
    except Exception:
        cl.restart_node(victim.node_id)
        fs.client._pull_node_list()
        fs.fsync(fh)
    fs.close(fh)
    assert cl.cos.get_object("b", "small.bin")[0] == data
    cl.close()


def test_replay_is_idempotent_across_double_restart(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _put_big_dirty(fs, "/b/i.bin", 2 * CHUNK)
    for nm in list(cl.node_list()):
        cl.crash_node(nm)
        cl.restart_node(nm)
        cl.crash_node(nm)
        cl.restart_node(nm)
    assert fs.read_file("/b/i.bin") == data
    cl.close()


def test_compaction_preserves_state(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _put_big_dirty(fs, "/b/c.bin", 2 * CHUNK + 7)
    for s in cl.servers.values():
        before = s.raft.size_bytes()
        s.compact()
        assert s.raft.size_bytes() <= before
    # state intact after compaction + restart
    for nm in list(cl.node_list()):
        cl.crash_node(nm)
        cl.restart_node(nm)
    assert fs.read_file("/b/c.bin") == data
    cl.close()
