"""Pipelined background flusher (§5.2 / Figs. 12-14): concurrency speedup,
crash-during-flush orphan-MPU recovery, dirty-page backpressure, priority
eviction, and truthful RPC payload accounting."""

import numpy as np
import pytest

from repro.core import BucketMount, Cluster, InodeKind, ServerConfig
from conftest import CHUNK, make_cluster, make_fs


def _blob(n, seed=0):
    return bytes(np.random.default_rng(seed).integers(0, 256, size=n,
                                                      dtype=np.uint8))


def _make_cluster(workdir, n=3, **cfg_kw):
    cfg = ServerConfig(chunk_size=CHUNK, **cfg_kw)
    cl = Cluster(workdir, [BucketMount("b", "b")], cfg=cfg)
    cl.start(n)
    return cl


def _dirty_files(fs, count, nbytes, seed0=0):
    files = {}
    for i in range(count):
        p = f"/b/f{i}.bin"
        d = _blob(nbytes, seed0 + i)
        fs.write_file(p, d)
        files[p] = d
    return files


def _dirty_file_metas(cl):
    """Dirty FILE inodes only — live directories stay dirty by design
    (they persist at zero scale), so drain tests must not count them."""
    seen = set()
    for s in cl.servers.values():
        for ino in s.metas.dirty_inos():
            m = s.metas.get(ino)
            if m and m.kind == InodeKind.FILE:
                seen.add(ino)
    return len(seen)


def _meta_owner(cl, cos_key):
    for s in cl.servers.values():
        for ino in s.metas.dirty_inos():
            m = s.metas.get(ino)
            if m and m.cos_key == cos_key:
                return s
    return None


# =========================================================================
# pipelining: concurrent drain beats the serial baseline
# =========================================================================

def test_pipelined_drain_faster_than_serial(workdir):
    """Two identical clusters with identical dirty sets: the flusher's
    windowed drain must finish in well under half the serial virtual time."""
    times = {}
    for mode in ("serial", "pipelined"):
        cl = _make_cluster(workdir + "-" + mode, n=3)
        fs = make_fs(cl)
        _dirty_files(fs, 32, CHUNK // 2)
        t0 = cl.clock.now
        cl.drain_dirty(serial=(mode == "serial"))
        times[mode] = cl.clock.now - t0
        assert _dirty_file_metas(cl) == 0
        cl.close()
    assert times["pipelined"] * 2 <= times["serial"], times


def test_flusher_drain_lands_all_data_in_cos(workdir):
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl)
    files = _dirty_files(fs, 8, 2 * CHUNK + 17)
    cl.drain_dirty()
    for p, d in files.items():
        assert cl.cos.get_object("b", p[len("/b/"):])[0] == d, p
    assert cl.cos.outstanding_mpus() == []
    cl.close()


def test_poll_respects_flush_interval(workdir):
    cl = _make_cluster(workdir, n=2, flush_interval_s=5.0)
    fs = make_fs(cl)
    _dirty_files(fs, 3, CHUNK // 4)
    n1, _ = cl.poll_flush()          # first poll: interval elapsed at start
    cl.clock.advance_to(cl.clock.now + 1.0)
    fs.write_file("/b/late.bin", _blob(CHUNK // 4, 99))
    n2, _ = cl.poll_flush()          # 1s later: not due, nothing flushed
    assert n2 == 0
    cl.clock.advance_to(cl.clock.now + 5.0)
    n3, _ = cl.poll_flush()          # past the interval: flushes
    assert n1 + n3 >= 4
    cl.close()


def test_tick_counters_observable(workdir):
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl)
    _dirty_files(fs, 6, CHUNK)
    cl.drain_dirty()
    dc = cl.dirty_counts()
    assert dc["ticks"] >= 1
    assert dc["inodes_flushed"] >= 6
    assert dc["bytes_uploaded"] >= 6 * CHUNK
    assert dc["dirty_bytes"] == 0
    cl.close()


# =========================================================================
# crash during background flush: orphan MPUs must be aborted at recovery
# =========================================================================

@pytest.mark.parametrize("crash_point", ["persist_after_mpu_begin",
                                         "persist_after_put",
                                         "persist_after_mpu_commit"])
def test_crash_mid_flush_recovers_clean(workdir, crash_point):
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl)
    size = CHUNK // 2 if crash_point == "persist_after_put" else 3 * CHUNK
    data = _blob(size, 7)
    fs.write_file("/b/victim.bin", data)
    files = _dirty_files(fs, 4, CHUNK, seed0=20)

    victim = _meta_owner(cl, "victim.bin")
    if victim is None:
        pytest.skip("meta owner not observable")
    victim.arm_crash(crash_point)

    # the flusher absorbs the crash (flush_errors), other inodes proceed
    cl.tick_flush()
    assert cl.dirty_counts()["flush_errors"] >= 0  # counter exists

    # recovery replays the WAL and aborts any orphan MPU whose begin was
    # logged but that never reached commit/abort (Fig. 8 black dots)
    cl.restart_node(victim.node_id)
    assert cl.cos.outstanding_mpus() == []

    fs.client._pull_node_list()
    cl.drain_dirty()
    assert cl.cos.outstanding_mpus() == []
    assert cl.cos.get_object("b", "victim.bin")[0] == data
    for p, d in files.items():
        assert cl.cos.get_object("b", p[len("/b/"):])[0] == d, p
    assert _dirty_file_metas(cl) == 0
    cl.close()


def test_orphan_mpu_abort_counter(workdir):
    """A crash right after MPU-begin is Raft-logged leaves an orphan upload;
    restart must abort it at COS and bump the recovery counter."""
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    fs.write_file("/b/orph.bin", _blob(3 * CHUNK, 3))
    victim = _meta_owner(cl, "orph.bin")
    if victim is None:
        pytest.skip("meta owner not observable")
    victim.arm_crash("persist_after_mpu_begin")
    cl.tick_flush()
    assert len(cl.cos.outstanding_mpus()) >= 1   # crash left the orphan
    cl.restart_node(victim.node_id)
    assert cl.cos.outstanding_mpus() == []
    assert cl.servers[victim.node_id].stats.get("mpu_orphan_aborted", 0) >= 1
    # the inode is still dirty and a later flush succeeds
    fs.client._pull_node_list()
    cl.drain_dirty()
    assert cl.cos.exists("b", "orph.bin")
    cl.close()


# =========================================================================
# dirty-page backpressure + priority eviction
# =========================================================================

def test_backpressure_stalls_foreground_writes(workdir):
    cl = _make_cluster(workdir, n=2,
                       dirty_hiwater_bytes=CHUNK,
                       dirty_lowater_bytes=CHUNK // 2)
    fs = make_fs(cl)
    _dirty_files(fs, 6, CHUNK)
    assert fs.client.stats.get("bp_stalls", 0) >= 1     # client throttled
    assert sum(s.stats.get("bp_stalls", 0)
               for s in cl.servers.values()) >= 1       # server hinted
    cl.drain_dirty()
    assert cl.dirty_counts()["backpressure_stalls"] >= 1
    assert _dirty_file_metas(cl) == 0
    cl.close()


def test_priority_eviction_coldest_largest_first(workdir):
    cl = _make_cluster(workdir, n=2,
                       dirty_hiwater_bytes=CHUNK,
                       dirty_lowater_bytes=CHUNK // 2)
    fs = make_fs(cl)
    # oldest+largest file first, then newer smaller ones
    fs.write_file("/b/cold_big.bin", _blob(2 * CHUNK, 1))
    cl.clock.advance_to(cl.clock.now + 10.0)
    fs.write_file("/b/warm.bin", _blob(CHUNK // 2, 2))
    cl.clock.advance_to(cl.clock.now + 10.0)
    fs.write_file("/b/hot.bin", _blob(CHUNK // 4, 3))

    fl = cl.flusher
    assert fl.under_pressure()
    cands = fl._candidates()
    cands.sort(key=lambda c: (c[3], -c[2], c[1]))
    order = []
    for _node, ino, _size, _mtime in cands:
        for s in cl.servers.values():
            m = s.metas.get(ino)
            if m is not None and m.cos_key:
                order.append(m.cos_key)
                break
    assert order[0] == "cold_big.bin", order
    cl.tick_flush(max_inodes=1)
    assert cl.dirty_counts()["eviction_priority_picks"] >= 1
    assert cl.cos.exists("b", "cold_big.bin")
    cl.drain_dirty()
    cl.close()


def test_no_backpressure_below_watermark(workdir):
    cl = make_cluster(workdir, n=2)      # default 256 MiB hiwater
    fs = make_fs(cl)
    _dirty_files(fs, 3, CHUNK)
    assert fs.client.stats.get("bp_stalls", 0) == 0
    assert not cl.flusher.under_pressure()
    cl.drain_dirty()
    assert cl.dirty_counts()["eviction_priority_picks"] == 0
    cl.close()


# =========================================================================
# RPC payload accounting (satellite: truthful byte stats)
# =========================================================================

def test_upload_part_bytes_reflect_payload(workdir):
    """`rpc_upload_part` carries a control request, but the part payload
    (owner -> COS) must appear in the fabric byte stats (nbytes_extra)."""
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl)
    _dirty_files(fs, 6, 4 * CHUNK)
    cl.drain_dirty()
    stats = cl.rpc_stats()
    up = stats.get("rpc_upload_part")
    if up is None:
        pytest.skip("all chunk owners colocated with coordinators")
    # each remote part moves ~CHUNK of data; control-only accounting
    # (256B out + reply) would undercount by three orders of magnitude
    assert up["bytes"] >= up["calls"] * (CHUNK // 2), up
    cl.close()


def test_migrate_chunk_bytes_reflect_payload(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    _dirty_files(fs, 6, 2 * CHUNK)
    cl.add_node()
    stats = cl.rpc_stats()
    mv = stats.get("rpc_migrate_recv_chunk")
    if mv is None or mv["calls"] == 0:
        pytest.skip("no dirty chunks crossed nodes on this ring layout")
    assert mv["bytes"] >= mv["calls"] * (CHUNK // 2), mv
    cl.close()
