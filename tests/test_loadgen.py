"""Determinism and correctness of the open-loop load generator.

The harness must be bit-reproducible: same seed ⇒ identical arrival
schedule, per-tenant op mix, and filesystem end-state; and a schedule must
survive a round-trip through its JSON trace format.  All runs use fixed
seeds and the virtual clock — no wall-clock dependence anywhere."""

import json

from conftest import make_cluster
from repro.core import (ClientConfig, ObjcacheClient, ObjcacheFS,
                        OnOffArrivals, OpenLoopRunner, PoissonArrivals,
                        Schedule, TenantSpec, TraceArrivals, build_schedule,
                        fs_fingerprint, loadtest_hw, summarize)

import numpy as np


def _catalog(cl, tenants=("a", "b")):
    # fixed client id: the global counter's decimal width leaks into staged
    # part keys (payload bytes), perturbing virtual timing across clusters
    fs = ObjcacheFS(ObjcacheClient(
        cl.router, cl.clock, cl.node_list()[0],
        ClientConfig(consistency="strict"), chunk_size=cl.cfg.chunk_size,
        client_id=9001))
    for t in tenants:
        fs.makedirs(f"/bench/{t}")
    dirs, files = [], []
    for d in range(3):
        dp = f"/data{d}"
        fs.mkdir(dp)
        dirs.append(dp)
        for i in range(6):
            p = f"{dp}/f{i}.bin"
            fs.write_file(p, bytes(2048))
            files.append(p)
    return fs, files, dirs


def _tenants():
    return [
        TenantSpec("a", PoissonArrivals(150), n_clients=32, write_bytes=2048),
        TenantSpec("b", OnOffArrivals(300, mean_on_s=0.1, mean_off_s=0.1),
                   n_clients=32, write_bytes=2048),
    ]


def test_same_seed_identical_schedule():
    fd = [f"/data0/f{i}.bin" for i in range(6)], ["/data0"]
    s1 = build_schedule(_tenants(), fd[0], fd[1], horizon_s=0.5, seed=42)
    s2 = build_schedule(_tenants(), fd[0], fd[1], horizon_s=0.5, seed=42)
    assert s1.events == s2.events
    assert len(s1.events) > 20
    # events are time-ordered and all inside the horizon
    ts = [ev.t for ev in s1.events]
    assert ts == sorted(ts)
    assert all(0.0 <= t < 0.5 for t in ts)
    s3 = build_schedule(_tenants(), fd[0], fd[1], horizon_s=0.5, seed=43)
    assert s1.events != s3.events


def test_adding_a_tenant_preserves_existing_streams():
    """Per-tenant seed substreams: tenant a's events are byte-identical
    whether or not tenant b exists."""
    files = [f"/data0/f{i}.bin" for i in range(6)]
    both = build_schedule(_tenants(), files, ["/data0"], 0.5, seed=7)
    solo = build_schedule(_tenants()[:1], files, ["/data0"], 0.5, seed=7)
    assert [e for e in both.events if e.tenant == "a"] == solo.events


def test_trace_format_roundtrip():
    files = [f"/data0/f{i}.bin" for i in range(6)]
    sched = build_schedule(_tenants(), files, ["/data0"], 0.4, seed=9)
    payload = json.loads(json.dumps(sched.to_payload()))
    back = Schedule.from_payload(payload)
    assert back.horizon_s == sched.horizon_s
    assert back.seed == sched.seed
    assert back.events == sched.events
    # and a replayed trace drives TraceArrivals verbatim
    offsets = tuple(ev.t for ev in sched.events if ev.tenant == "a")
    rng = np.random.default_rng(0)
    assert TraceArrivals(offsets).times(0.4, rng) == list(offsets)
    assert TraceArrivals(offsets).times(0.1, rng) == \
        [t for t in offsets if t < 0.1]


def test_per_tenant_op_mix_deterministic():
    files = [f"/data0/f{i}.bin" for i in range(6)]
    sched = build_schedule(_tenants(), files, ["/data0"], 0.5, seed=11)
    mix = {}
    for ev in sched.events:
        mix.setdefault(ev.tenant, {}).setdefault(ev.op, 0)
        mix[ev.tenant][ev.op] += 1
    again = {}
    for ev in build_schedule(_tenants(), files, ["/data0"], 0.5,
                             seed=11).events:
        again.setdefault(ev.tenant, {}).setdefault(ev.op, 0)
        again[ev.tenant][ev.op] += 1
    assert mix == again
    # the default mix is stat-heavy for every tenant
    for t, ops in mix.items():
        assert ops.get("stat", 0) >= ops.get("create", 0)


def test_zipf_popularity_is_heavy_tailed():
    files = [f"/data0/f{i}.bin" for i in range(6)]
    spec = TenantSpec("a", PoissonArrivals(2000), n_clients=32, zipf_s=1.3)
    sched = build_schedule([spec], files, ["/data0"], 0.5, seed=3)
    counts = {}
    for ev in sched.events:
        if ev.op in ("stat", "read", "write"):
            counts[ev.path] = counts.get(ev.path, 0) + 1
    ranked = sorted(counts.values(), reverse=True)
    # the most popular file dominates the least popular by a wide margin
    assert ranked[0] > 3 * ranked[-1]


def _run_once(workdir, seed=17):
    cl = make_cluster(workdir, n=2, chunk=64 * 1024, hw=loadtest_hw())
    try:
        _, files, dirs = _catalog(cl)
        tenants = _tenants()
        sched = build_schedule(tenants, files, dirs, horizon_s=0.4, seed=seed)
        runner = OpenLoopRunner(cl, tenants, consistency="strict",
                                pool_per_tenant=4)
        results = runner.run(sched)
        summary = summarize(results, 0.4)
        reader = ObjcacheFS(ObjcacheClient(
            cl.router, cl.clock, cl.node_list()[0],
            ClientConfig(consistency="strict"),
            chunk_size=cl.cfg.chunk_size, client_id=9002))
        fp = fs_fingerprint(reader)
        return summary, fp, [(r.ev.t, r.status, r.latency_s) for r in results]
    finally:
        cl.close()


def test_same_seed_identical_end_state_and_summary(workdir):
    import os
    d1, d2 = os.path.join(workdir, "a"), os.path.join(workdir, "b")
    os.makedirs(d1)
    os.makedirs(d2)
    s1, fp1, r1 = _run_once(d1)
    s2, fp2, r2 = _run_once(d2)
    assert r1 == r2            # per-op status AND virtual-time latency
    assert s1 == s2
    assert fp1 == fp2
    assert s1["overall"]["ok"] > 0
    assert s1["overall"]["err"] == 0


def test_open_loop_latency_counts_queueing(workdir):
    """Two ops scheduled at (nearly) the same arrival: the second one's
    latency includes waiting for the first — the whole point of open loop."""
    cl = make_cluster(workdir, n=2, chunk=64 * 1024, hw=loadtest_hw())
    try:
        _, files, dirs = _catalog(cl, tenants=("a",))
        spec = TenantSpec("a", TraceArrivals((0.0, 0.0, 0.0, 0.0)),
                          n_clients=1,
                          op_mix={"write": 1.0}, write_bytes=32768)
        sched = build_schedule([spec], files, dirs, horizon_s=1.0, seed=5)
        assert len(sched.events) == 4
        runner = OpenLoopRunner(cl, [spec], consistency="strict",
                                pool_per_tenant=1)
        results = runner.run(sched)
        lats = [r.latency_s for r in results]
        # same client pool slot, same arrival instant: strictly increasing
        # completion times mean each op queued behind the previous one
        assert lats == sorted(lats)
        assert lats[-1] > 2 * lats[0]
    finally:
        cl.close()
