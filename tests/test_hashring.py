"""Consistent hashing invariants (property-based)."""

from proptest import given, settings, st

from repro.core import HashRing

nodes_st = st.lists(st.sampled_from([f"n{i}" for i in range(12)]),
                    min_size=1, max_size=8, unique=True)
keys_st = st.lists(st.text(min_size=1, max_size=12), min_size=1,
                   max_size=40, unique=True)


@given(nodes_st, keys_st)
@settings(max_examples=50, deadline=None)
def test_lookup_deterministic_and_member(nodes, keys):
    ring = HashRing(nodes)
    for k in keys:
        owner = ring.node_for(k)
        assert owner in nodes
        assert ring.node_for(k) == owner


@given(nodes_st, keys_st, st.sampled_from([f"m{i}" for i in range(4)]))
@settings(max_examples=50, deadline=None)
def test_join_moves_keys_only_to_joiner(nodes, keys, joiner):
    """§4.3: a node join affects only keys that move TO the joiner."""
    before = HashRing(nodes)
    after = before.copy()
    after.add_node(joiner)
    for k in keys:
        a, b = before.node_for(k), after.node_for(k)
        if a != b:
            assert b == joiner


@given(nodes_st.filter(lambda n: len(n) >= 2), keys_st)
@settings(max_examples=50, deadline=None)
def test_leave_moves_only_leavers_keys(nodes, keys):
    before = HashRing(nodes)
    leaver = nodes[0]
    after = before.copy()
    after.remove_node(leaver)
    for k in keys:
        a, b = before.node_for(k), after.node_for(k)
        if a != leaver:
            assert a == b  # keys not owned by the leaver never move


@given(st.integers(2, 8), st.integers(200, 400))
@settings(max_examples=10, deadline=None)
def test_balance_rough(n_nodes, n_keys):
    """Virtual nodes keep the max/mean load ratio bounded."""
    nodes = [f"n{i}" for i in range(n_nodes)]
    ring = HashRing(nodes, vnodes=64)
    counts = {n: 0 for n in nodes}
    for i in range(n_keys):
        counts[ring.node_for(f"key-{i}")] += 1
    mean = n_keys / n_nodes
    assert max(counts.values()) < 3.5 * mean
