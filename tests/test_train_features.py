"""Training-step features: gradient accumulation equivalence, gradient
compression, LR schedule, clipping."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models import build_model
from repro.optim import AdamWConfig, lr_schedule
from repro.train import make_train_step, train_state_init

B, S = 4, 32


def _setup(arch="qwen3-0.6b"):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=S)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    return model, state, batch


def test_grad_accumulation_matches_full_batch():
    model, state, batch = _setup()
    opt = AdamWConfig(warmup_steps=1, total_steps=10)
    s1, m1 = jax.jit(make_train_step(model, opt))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt, accum_steps=2))(state,
                                                                 batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=2e-3)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-3)


def test_grad_compression_close_to_fp32():
    model, state, batch = _setup()
    opt = AdamWConfig(warmup_steps=1, total_steps=10)
    s1, m1 = jax.jit(make_train_step(model, opt))(state, batch)
    s2, m2 = jax.jit(make_train_step(model, opt,
                                     reduce_dtype="bfloat16"))(state, batch)
    # bf16 gradient reduction perturbs but must not derail the update
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m2["grad_norm"]), rtol=2e-2)
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(s1.params),
                             jax.tree.leaves(s2.params))]
    assert max(diffs) < 1e-2


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in
           (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5e-3) < 1e-9  # linear warmup
    assert abs(lrs[2] - 1e-3) < 1e-9   # peak
    assert lrs[3] < lrs[2]             # cosine decay
    assert abs(lrs[4] - 1e-4) < 1e-9   # floor


def test_clipping_engages_on_large_grads():
    model, state, batch = _setup()
    opt = AdamWConfig(warmup_steps=1, total_steps=10, clip_norm=1e-6)
    _, metrics = jax.jit(make_train_step(model, opt))(state, batch)
    assert float(metrics["clip_scale"]) < 1.0
