"""Property-test front-end: real `hypothesis` when installed, otherwise a
deterministic example-based fallback.

The fallback implements just the strategy surface our tests use
(`integers`, `sampled_from`, `text`, `tuples`, `lists`, `.filter`) as
seeded draw functions, and `given` becomes a `pytest.mark.parametrize`
over a fixed number of pre-drawn examples — deterministic across runs,
and fixture injection keeps working because parametrize matches argument
names (`given`'s positional strategies map to the test's rightmost
parameters, same as hypothesis).
"""

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
except ModuleNotFoundError:
    import inspect
    import random
    import string

    import pytest

    N_EXAMPLES = 12
    _SEED = 0xA11CE


    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    v = self.draw(rng)
                    if pred(v):
                        return v
                raise ValueError("filter predicate too restrictive")
            return _Strategy(draw)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self.draw(rng)))


    class st:  # noqa: N801  (mimics `hypothesis.strategies` module surface)
        @staticmethod
        def integers(min_value=0, max_value=1 << 30):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def text(alphabet=string.ascii_lowercase + string.digits,
                 min_size=0, max_size=12):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return "".join(rng.choice(alphabet) for _ in range(n))
            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

        @staticmethod
        def lists(elem, min_size=0, max_size=10, unique=False):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                out = []
                for _ in range(50 * max(n, 1)):
                    if len(out) >= n:
                        break
                    v = elem.draw(rng)
                    if unique and v in out:
                        continue
                    out.append(v)
                # tiny unique domain: degrade to the elements that exist
                # (hypothesis would shrink the same way); only a domain with
                # nothing to draw at all is a hard error
                if not out and min_size > 0:
                    raise ValueError("cannot draw any unique elements")
                return out
            return _Strategy(draw)


    def given(*strategies):
        def deco(fn):
            params = list(inspect.signature(fn).parameters)
            names = params[-len(strategies):]
            rng = random.Random(_SEED)
            # single argname: parametrize expects bare values, not 1-tuples
            examples = [strategies[0].draw(rng) if len(strategies) == 1
                        else tuple(s.draw(rng) for s in strategies)
                        for _ in range(N_EXAMPLES)]
            return pytest.mark.parametrize(",".join(names), examples)(fn)
        return deco


    def settings(*args, **kwargs):
        return lambda fn: fn
