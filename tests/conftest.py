import shutil
import tempfile

import pytest

from repro.core import (BucketMount, ClientConfig, Cluster, ObjcacheClient,
                        ObjcacheFS, ServerConfig)

CHUNK = 256 * 1024   # small chunks so multi-chunk paths trigger quickly

# Modules whose tests are all `slow` (JAX smoke): skip collecting them under
# the default `-m 'not slow'` so tier-1 never pays their import-time JAX cost.
_SLOW_MODULES = {"test_kernels.py", "test_models_smoke.py",
                 "test_dryrun_integration.py"}


def pytest_ignore_collect(collection_path, config):
    if collection_path.name in _SLOW_MODULES and \
            config.option.markexpr == "not slow":
        return True
    return None


@pytest.fixture()
def workdir():
    d = tempfile.mkdtemp(prefix="objcache-test-")
    yield d
    shutil.rmtree(d, ignore_errors=True)


def make_cluster(workdir, n=3, chunk=CHUNK, buckets=None, hw=None, cfg=None,
                 backends=None, clock=None):
    cfg = cfg or ServerConfig(chunk_size=chunk)
    cl = Cluster(workdir, buckets or [BucketMount("b", "b")], hw=hw, cfg=cfg,
                 backends=backends, clock=clock)
    cl.start(n)
    return cl


def make_fs(cl, consistency="strict", deployment="detached", node=None):
    client = ObjcacheClient(cl.router, cl.clock,
                            node or cl.node_list()[0],
                            ClientConfig(consistency=consistency,
                                         deployment=deployment),
                            chunk_size=cl.cfg.chunk_size)
    return ObjcacheFS(client)


@pytest.fixture()
def cluster(workdir):
    cl = make_cluster(workdir)
    yield cl
    cl.close()


@pytest.fixture()
def fs(cluster):
    return make_fs(cluster)
