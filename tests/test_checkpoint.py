"""Checkpoint manager over objcache: transactional commit, roundtrip,
resume-after-crash, and write-back overlap accounting."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.models import build_model
from repro.train import train_state_init
from conftest import make_cluster, make_fs


def test_roundtrip_preserves_tree_and_values(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    model = build_model(get_reduced("qwen3-0.6b"))
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=32)
    ckpt = CheckpointManager(fs, "/b/ckpt")
    ckpt.save(3, state)
    restored = ckpt.restore(3, like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert ckpt.latest_step() == 3
    cl.close()


def test_manifest_is_commit_point(workdir):
    """A save without a manifest (simulated torn save) is invisible."""
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    ckpt = CheckpointManager(fs, "/b/ckpt")
    fs.makedirs("/b/ckpt/step_9")
    fs.write_file("/b/ckpt/step_9/orphan.bin", b"xxxx")
    assert ckpt.latest_step() is None
    ckpt.save(10, {"w": jnp.ones((4, 4))})
    assert ckpt.latest_step() == 10
    cl.close()


def test_durable_save_lands_in_cos(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    ckpt = CheckpointManager(fs, "/b/ckpt")
    tree = {"w": jnp.arange(1024, dtype=jnp.float32)}
    ckpt.save(1, tree, durable=True)
    assert cl.cos.exists("b", "ckpt/step_1/w.bin")
    raw, _ = cl.cos.get_object("b", "ckpt/step_1/w.bin")
    np.testing.assert_array_equal(np.frombuffer(raw, np.float32),
                                  np.arange(1024, dtype=np.float32))
    cl.close()


def test_resume_after_cluster_crash(workdir):
    """Checkpoint saved, every node crash/restarts, restore still works
    (WAL replay reconstructs cluster-local chunks)."""
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    model = build_model(get_reduced("mamba2-370m"))
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=32)
    ckpt = CheckpointManager(fs, "/b/ckpt")
    ckpt.save(7, state)
    for nm in list(cl.node_list()):
        cl.crash_node(nm)
        cl.restart_node(nm)
    restored = ckpt.restore(7, like=state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    cl.close()


def test_async_writeback_overlaps(workdir):
    """save() returns at cluster-commit time; the COS upload happens in the
    background flush — the virtual-time gap is the Fig. 12 overlap."""
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    ckpt = CheckpointManager(fs, "/b/ckpt")
    tree = {"w": jnp.ones((1 << 20,), jnp.float32)}   # 4 MB
    t0 = cl.clock.now
    ckpt.save(1, tree)
    t_commit = cl.clock.now - t0
    assert not cl.cos.exists("b", "ckpt/step_1/w.bin")   # not uploaded yet
    cl.drain_dirty()
    assert cl.cos.exists("b", "ckpt/step_1/w.bin")
    # cluster-local commit must be much faster than the full COS upload
    upload_s = (4 << 20) / cl.hw.cos_conn_bps
    assert t_commit < upload_s
    cl.close()
