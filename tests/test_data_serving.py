"""Data pipeline + serving engine over objcache."""

import json

import jax
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.data import TokenPipeline, synth_corpus_to_cos
from repro.models import build_model
from repro.serving import ModelStore, ServingEngine
from repro.train import train_state_init
from conftest import make_cluster, make_fs


def test_pipeline_deterministic_and_cache_warms(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    synth_corpus_to_cos(cl.cos, "b", "corpus", n_shards=3,
                        tokens_per_shard=4 * 33 * 4, vocab=100)
    pipe = TokenPipeline(fs, "/b/corpus", batch=4, seq_len=32)
    b1 = [b["tokens"].copy() for b in pipe.batches(epoch=0)]
    t_cold = cl.clock.now
    b2 = [b["tokens"].copy() for b in pipe.batches(epoch=0)]
    t_warm = cl.clock.now - t_cold
    assert len(b1) == len(b2) > 0
    for a, b in zip(b1, b2):
        np.testing.assert_array_equal(a, b)
    assert t_warm < t_cold          # second epoch hits the cache tiers
    # labels shift by one within the packed stream
    batch = next(iter(pipe.batches(epoch=0)))
    assert batch["tokens"].shape == (4, 32)
    assert batch["labels"].shape == (4, 32)
    cl.close()


def test_model_store_and_engine_generate(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=64)
    CheckpointManager(fs, "/b/models/m").save(0, state.params, durable=True)

    store = ModelStore(fs, "/b/models/m")
    params, nbytes = store.load(0, like=state.params)
    assert nbytes > 0
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    engine = ServingEngine(model, params, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=5, dtype=np.int32)
               for _ in range(3)]
    outs = engine.generate(prompts, max_new=4)
    assert len(outs) == 3 and all(len(o) == 4 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)
    cl.close()


def test_model_store_load_missing_leaf_and_dtype_mismatch(workdir):
    """A manifest that drops a leaf or lies about a dtype must fail loudly
    (named leaf in the message), never deserialize garbage."""
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=32)
    CheckpointManager(fs, "/b/models/m").save(0, state.params)
    store = ModelStore(fs, "/b/models/m")
    man_path = "/b/models/m/step_0/manifest.json"
    manifest = json.loads(fs.read_file(man_path))
    victim = sorted(manifest["leaves"])[0]

    # missing manifest leaf
    broken = {"step": 0, "leaves": {k: v for k, v in
                                    manifest["leaves"].items()
                                    if k != victim}}
    fs.write_file(man_path, json.dumps(broken).encode())
    with pytest.raises(ValueError, match="missing leaves") as ei:
        store.load(0, like=state.params)
    assert victim in str(ei.value)

    # dtype mismatch: manifest claims a wider dtype than the bytes on disk
    lied = json.loads(json.dumps(manifest))
    lied["leaves"][victim]["dtype"] = "float64"
    fs.write_file(man_path, json.dumps(lied).encode())
    with pytest.raises(ValueError, match="bytes on disk"):
        store.load(0, like=state.params)

    # restored manifest loads fine again
    fs.write_file(man_path, json.dumps(manifest).encode())
    params, _ = store.load(0, like=state.params)
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    cl.close()


def test_cold_vs_warm_model_load_times(workdir):
    """Fig. 11 trend: cluster-warm load must beat the cold COS load."""
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    cfg = get_reduced("granite-8b")
    model = build_model(cfg)
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=32)
    CheckpointManager(fs, "/b/models/g").save(0, state.params, durable=True)
    # evict cluster-local state by scaling to zero and restarting
    for nm in list(cl.node_list()):
        cl.remove_node(nm)
    cl2 = make_cluster(workdir + "-2", n=3)
    cl2.cos = cl.cos
    for s in cl2.servers.values():
        s.cos = cl.cos
    fs2 = make_fs(cl2, consistency="weak")
    store = ModelStore(fs2, "/b/models/g")
    t0 = cl2.clock.now
    store.load(0, like=state.params)
    cold = cl2.clock.now - t0
    t0 = cl2.clock.now
    store.load(0, like=state.params)
    warm = cl2.clock.now - t0
    assert warm < cold
    cl2.close()
    cl.close()
