"""Raft WAL: append/replay, torn tails, mid-log corruption, compaction."""

import os

import pytest

from repro.core import ChecksumError, Cmd
from repro.core.raftlog import RaftLog
from repro.core.simclock import HardwareModel, SimClock


def make_log(workdir):
    clock = SimClock()
    return RaftLog(os.path.join(workdir, "log"), clock,
                   HardwareModel().make_disk("n0"))


def test_append_replay_roundtrip(workdir):
    log = make_log(workdir)
    for i in range(20):
        log.append(Cmd.LOCAL_META_UPDATE, {"i": i})
    log.close()
    log2 = make_log(workdir)
    entries = list(log2.replay())
    assert [e.payload["i"] for e in entries] == list(range(20))
    assert all(e.cmd == Cmd.LOCAL_META_UPDATE for e in entries)
    assert log2.next_index == 21
    log2.close()


def test_torn_tail_discarded(workdir):
    log = make_log(workdir)
    for i in range(5):
        log.append(Cmd.LOCAL_META_UPDATE, {"i": i})
    log.simulate_torn_tail(nbytes=3)
    entries = list(log.replay())
    assert [e.payload["i"] for e in entries] == [0, 1, 2, 3]
    # the log is usable again after replay truncation
    idx, _ = log.append(Cmd.LOCAL_META_UPDATE, {"i": 99})
    assert idx == 5
    log.close()


def test_mid_log_corruption_detected(workdir):
    log = make_log(workdir)
    for i in range(50):
        log.append(Cmd.LOCAL_META_UPDATE, {"i": i, "pad": "x" * 50})
    log.simulate_corruption(at_frac=0.4)
    with pytest.raises(ChecksumError):
        list(log.replay())
    log.close()


def test_bulk_roundtrip(workdir):
    log = make_log(workdir)
    blobs = [bytes([i]) * (1000 + i) for i in range(8)]
    refs = [log.append_bulk(b)[0] for b in blobs]
    for ref, blob in zip(refs, blobs):
        assert log.read_bulk(ref) == blob
    log.close()


def test_term_bumps_across_restart(workdir):
    log = make_log(workdir)
    t0 = log.term
    log.bump_term()
    log.close()
    log2 = make_log(workdir)
    assert log2.term == t0 + 1
    log2.close()


def test_compaction_shrinks_log(workdir):
    log = make_log(workdir)
    for i in range(100):
        log.append(Cmd.LOCAL_META_UPDATE, {"i": i, "pad": "y" * 200})
    before = log.size_bytes()
    log.compact({"snapshot": True})
    after = log.size_bytes()
    assert after < before / 10
    entries = list(log.replay())
    assert entries[0].cmd == Cmd.SNAPSHOT
    log.close()
