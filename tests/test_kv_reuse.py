"""KV-cache persistence over ObjcacheFS (serving/kvstore.py).

Numpy-only store semantics (hashing, snapshot/lookup contract, bit-exact
round-trips, shape adaptation, layer-ranged reads) plus the JAX serving
integration: the same prompt must emit identical tokens with and without
KV-prefix reuse, including across a simulated scale-down/warm-restart."""

import json

import numpy as np
import pytest

from repro.serving.kvstore import KVCacheStore, prefix_key
from conftest import make_cluster, make_fs


def _synthetic_cache(nper=2, batch=2, kv_len=32, seed=0):
    """A cache-shaped pytree mirroring models.lm.init_cache: an attention
    slot (bf16-ish halves) and an SSM slot (f32 state)."""
    rng = np.random.default_rng(seed)
    return {
        "slot0": {
            "k": rng.standard_normal((nper, batch, 2, kv_len, 8)
                                     ).astype(np.float16),
            "v": rng.standard_normal((nper, batch, 2, kv_len, 8)
                                     ).astype(np.float16),
        },
        "slot1": {
            "conv": rng.standard_normal((nper, batch, 3, 24)
                                        ).astype(np.float16),
            "ssm": rng.standard_normal((nper, batch, 4, 8, 8)
                                       ).astype(np.float32),
        },
    }


def test_prefix_key_dtype_stable():
    toks = [5, 1, 400, 7]
    assert prefix_key(toks) == prefix_key(np.asarray(toks, np.int64))
    assert prefix_key(toks) == prefix_key(np.asarray(toks, np.int32))
    assert prefix_key(toks) != prefix_key(toks[:-1])


def test_snapshot_and_candidate_lens():
    kv = KVCacheStore.__new__(KVCacheStore)
    kv.block_tokens = 16
    assert kv.snapshot_lens(48) == [16, 32, 47]
    assert kv.snapshot_lens(16) == [15]
    assert kv.snapshot_lens(1) == []
    assert kv.candidate_lens(47) == [47, 32, 16]
    assert kv.candidate_lens(16) == [16]
    assert kv.candidate_lens(0) == []


def test_put_get_roundtrip_bitexact(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    kv = KVCacheStore(fs, "/b/kv", block_tokens=16)
    cache = _synthetic_cache(kv_len=32)
    toks = np.arange(32, dtype=np.int32)
    man = kv.put(toks, cache, batch_index=1)
    assert man is not None and man["cache_len"] == 32
    # second put of the same prefix is a no-op (immutable blocks)
    assert kv.put(toks, cache, batch_index=0) is None
    got, man2 = kv.get(man["key"], like=cache)
    assert man2["nbytes"] == man["nbytes"]
    for path in ("slot0/k", "slot0/v", "slot1/conv", "slot1/ssm"):
        a, b = path.split("/")
        stored = got[a][b]
        assert stored.shape[1] == 1            # batch-1 restore
        np.testing.assert_array_equal(stored[:, 0], cache[a][b][:, 1])
    cl.close()


def test_lookup_longest_prefix_and_cap(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    kv = KVCacheStore(fs, "/b/kv", block_tokens=16)
    cache = _synthetic_cache(kv_len=64)
    prompt = np.arange(100, 148, dtype=np.int32)        # 48 tokens
    for ln in kv.snapshot_lens(48):                      # 16, 32, 47
        kv.put(prompt[:ln], cache)
    assert kv.lookup(prompt, cap=47) == (47, prefix_key(prompt[:47]))
    # a different continuation past 32 still reuses the 32-block
    other = np.concatenate([prompt[:40], np.full(8, 9999, np.int32)])
    assert kv.lookup(other, cap=39)[0] == 32
    # diverging before the first block: miss
    assert kv.lookup(np.full(48, 7, np.int32), cap=47) is None
    cl.close()


def test_get_adapts_kv_axis_and_rejects_bad(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    kv = KVCacheStore(fs, "/b/kv", block_tokens=8)
    cache = _synthetic_cache(kv_len=32)
    toks = np.arange(16, dtype=np.int32)   # cache_len 16 < kv_len 32
    man = kv.put(toks, cache)
    # reader with a larger max_len: kv axis zero-padded, live range exact
    bigger = _synthetic_cache(kv_len=48, seed=1)
    got, _ = kv.get(man["key"], like=bigger)
    assert got["slot0"]["k"].shape[3] == 48
    np.testing.assert_array_equal(got["slot0"]["k"][:, 0, :, :32],
                                  cache["slot0"]["k"][:, 0])
    assert not got["slot0"]["k"][:, 0, :, 32:].any()
    # reader with a smaller max_len that still covers cache_len: sliced
    smaller = _synthetic_cache(kv_len=24, seed=2)
    got, _ = kv.get(man["key"], like=smaller)
    assert got["slot0"]["k"].shape[3] == 24
    # wrapped cache (cache_len == kv_len) cannot be resized
    full = kv.put(np.arange(32, dtype=np.int32), cache)
    with pytest.raises(ValueError, match="resize"):
        kv.get(full["key"], like=smaller)
    # dtype mismatch is an error, not a cast
    wrong = _synthetic_cache(kv_len=32)
    wrong["slot1"]["ssm"] = wrong["slot1"]["ssm"].astype(np.float16)
    with pytest.raises(ValueError, match="dtype"):
        kv.get(man["key"], like=wrong)
    cl.close()


def test_layer_subset_uses_ranged_reads(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    kv = KVCacheStore(fs, "/b/kv")
    cache = _synthetic_cache(kv_len=32)
    man = kv.put(np.arange(8, dtype=np.int32), cache)
    got, _ = kv.get(man["key"], layers={"slot1/ssm"})
    assert list(got) == ["slot1"] and list(got["slot1"]) == ["ssm"]
    np.testing.assert_array_equal(got["slot1"]["ssm"][:, 0],
                                  cache["slot1"]["ssm"][:, 0])
    # the subset read fetched only that leaf's blocks
    ssm_bytes = cache["slot1"]["ssm"][:, 0].nbytes
    assert kv.stats["get_bytes"] == ssm_bytes < man["nbytes"]
    cl.close()


def test_manifest_published_atomically(workdir):
    """A prefix directory without a renamed-in manifest is invisible."""
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    kv = KVCacheStore(fs, "/b/kv")
    toks = np.arange(8, dtype=np.int32)
    key = prefix_key(toks)
    fs.makedirs(f"/b/kv/{key}")
    fs.write_file(f"/b/kv/{key}/blocks.bin", b"garbage")
    assert kv.lookup(toks, cap=8) is None
    assert not kv.has(toks)
    cl.close()


def test_read_file_range(fs):
    data = bytes(range(256)) * 2048            # 512 KiB, 2 chunks
    fs.write_file("/b/rng.bin", data)
    assert fs.read_file_range("/b/rng.bin", 0, 16) == data[:16]
    off = 300_000                               # crosses the chunk boundary
    assert fs.read_file_range("/b/rng.bin", off - 10, 50) == \
        data[off - 10:off + 40]
    # short read at EOF, not an error
    assert fs.read_file_range("/b/rng.bin", len(data) - 8, 64) == data[-8:]


# ---------------------------------------------------------------------------
# JAX serving integration: reuse must not change emitted tokens
# ---------------------------------------------------------------------------
def _engine(arch, fs, kv_root=None, max_len=64, block_tokens=8):
    import jax
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.serving import ServingEngine

    cfg = get_reduced(arch)
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), max_seq=max_len)
    kv = KVCacheStore(fs, kv_root, block_tokens=block_tokens) \
        if kv_root else None
    return ServingEngine(model, params, max_len=max_len, kvstore=kv), cfg


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-370m"])
def test_reuse_tokens_identical(workdir, arch):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    engine, cfg = _engine(arch, fs, kv_root="/b/kv")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=21, dtype=np.int32)

    base = engine.generate([prompt], max_new=6)[0]       # no kvstore path
    cold, i_cold = engine.generate_with_reuse(prompt, max_new=6)
    assert cold == base
    assert i_cold["reused_len"] == 0 and i_cold["kv_stored"] > 0

    warm, i_warm = engine.generate_with_reuse(prompt, max_new=6)
    assert warm == base
    assert i_warm["exact_hit"] and i_warm["reused_len"] == len(prompt) - 1
    assert i_warm["prefill_steps"] == 1

    # a longer prompt sharing the prefix resumes from a block boundary
    longer = np.concatenate([prompt,
                             rng.integers(0, cfg.vocab, 9, dtype=np.int32)])
    ref = engine.generate([longer], max_new=6)[0]
    got, i_long = engine.generate_with_reuse(longer, max_new=6)
    assert got == ref
    assert i_long["reused_len"] >= 16        # ≥ the highest shared block
    cl.close()


def test_warm_restart_after_scale_down(workdir):
    """Fig. 11 shape for inference state: a replica restarted over the same
    COS bucket reloads hot KV blocks and emits the same tokens."""
    import jax  # noqa: F401  (keeps the slow import grouped here)

    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    engine, cfg = _engine("qwen3-0.6b", fs, kv_root="/b/kv")
    prompt = np.random.default_rng(5).integers(0, cfg.vocab, 17,
                                               dtype=np.int32)
    base, _ = engine.generate_with_reuse(prompt, max_new=5)
    assert cl.drain_dirty() >= 0             # KV blocks durable in COS
    for nm in list(cl.node_list()):          # simulated scale-down
        cl.remove_node(nm)

    cl2 = make_cluster(workdir + "-2", n=3)
    cl2.cos = cl.cos
    for s in cl2.servers.values():
        s.cos = cl.cos
    fs2 = make_fs(cl2, consistency="weak")
    engine2, _ = _engine("qwen3-0.6b", fs2, kv_root="/b/kv")
    got, info = engine2.generate_with_reuse(prompt, max_new=5)
    assert got == base
    assert info["exact_hit"] and info["kv_read_bytes"] > 0
    cl2.close()
    cl.close()
