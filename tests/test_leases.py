"""Client leases: grants, zero-RPC local serving, epoch invalidation under
rename/unlink/migration handoff, and WAL-replay re-derivation of epochs."""

import pytest

from repro.core import (Errno, OpenLoopRunner, PoissonArrivals, TenantSpec,
                        build_schedule, fs_fingerprint)
from repro.core.types import StaleLeaseError, meta_key
from conftest import make_cluster, make_fs


def _rpc_calls(cl, method):
    return cl.router.method_stats.get(method, {}).get("calls", 0)


def test_repeat_readdir_serves_locally(workdir):
    """A leased directory answers repeat readdirs with zero RPCs."""
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    fs.write_file("/b/a.bin", b"x")
    fs.write_file("/b/b.bin", b"y")
    first = fs.listdir("/b")
    calls = _rpc_calls(cl, "rpc_readdir")
    envelopes = cl.router.rpc_count
    for _ in range(5):
        assert fs.listdir("/b") == first
    assert _rpc_calls(cl, "rpc_readdir") == calls
    assert cl.router.rpc_count == envelopes
    assert fs.client.stats.get("lease_readdir_hits", 0) >= 5
    cl.close()


def test_repeat_lookup_serves_locally_including_negative(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    fs.write_file("/b/hit.bin", b"x")
    fs.listdir("/b")                       # takes the dir lease
    calls = _rpc_calls(cl, "rpc_lookup")
    assert fs.exists("/b/hit.bin")
    assert not fs.exists("/b/miss.bin")    # negative lookup also local
    assert _rpc_calls(cl, "rpc_lookup") == calls
    cl.close()


def test_lease_disabled_by_config(workdir):
    cl = make_cluster(workdir)
    cl.cfg.lease_ttl_s = 0.0
    fs = make_fs(cl, consistency="weak")
    fs.write_file("/b/a.bin", b"x")
    fs.listdir("/b")
    calls = _rpc_calls(cl, "rpc_readdir")
    fs.listdir("/b")
    assert _rpc_calls(cl, "rpc_readdir") > calls   # no local serving
    assert fs.client.stats.get("lease_readdir_hits", 0) == 0
    cl.close()


def test_stale_lease_refetched_after_remote_rename(workdir):
    """A committed rename bumps the parent epoch; the other client's renewal
    is rejected with ESTALE and transparently re-fetched."""
    cl = make_cluster(workdir)
    a = make_fs(cl, consistency="weak", node=cl.node_list()[0])
    b = make_fs(cl, consistency="weak", node=cl.node_list()[1])
    a.write_file("/b/old.bin", b"data")
    b.listdir("/b")                        # b takes a lease on /b
    a.rename("/b/old.bin", "/b/new.bin")
    # expire b's lease so the next readdir goes back as a renewal
    cl.clock.sleep(cl.cfg.lease_ttl_s + 0.001)
    names = b.listdir("/b")
    assert "new.bin" in names and "old.bin" not in names
    assert b.client.stats.get("lease_stale", 0) >= 1
    cl.close()


def test_stale_lease_refetched_after_remote_unlink(workdir):
    cl = make_cluster(workdir)
    a = make_fs(cl, consistency="weak", node=cl.node_list()[0])
    b = make_fs(cl, consistency="weak", node=cl.node_list()[1])
    a.write_file("/b/gone.bin", b"data")
    b.listdir("/b")
    a.unlink("/b/gone.bin")
    cl.clock.sleep(cl.cfg.lease_ttl_s + 0.001)
    assert "gone.bin" not in b.listdir("/b")
    assert b.client.stats.get("lease_stale", 0) >= 1
    cl.close()


def test_open_sees_remote_close_via_epoch_renewal(workdir):
    """Close-to-open: even inside the TTL, open()'s validation getattr is a
    renewal that carries the epoch, so a remote write+close is never hidden
    behind a still-live lease."""
    cl = make_cluster(workdir)
    w = make_fs(cl, consistency="weak", node=cl.node_list()[0])
    r = make_fs(cl, consistency="weak", node=cl.node_list()[1])
    w.write_file("/b/c2o.bin", b"AAAA")
    fh = r.open("/b/c2o.bin", "r")
    assert r.read(fh, 0, 4) == b"AAAA"
    r.close(fh)
    fh = w.open("/b/c2o.bin", "r+")
    w.write(fh, 0, b"BBBB")
    w.close(fh)
    fh = r.open("/b/c2o.bin", "r")       # within the lease TTL
    assert r.read(fh, 0, 4) == b"BBBB"
    r.close(fh)
    cl.close()


def test_server_rejects_stale_epoch_directly(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    fs.write_file("/b/f.bin", b"x")
    ino = fs.resolve("/b/f.bin")
    s = cl.servers[cl.any_server().owner(meta_key(ino))]
    epoch = s.state.lease_epoch(ino)
    res, _ = s.rpc_getattr(0.0, ino=ino, lease_epoch=epoch)
    assert res["lease"]["epoch"] == epoch
    with pytest.raises(StaleLeaseError) as ei:
        s.rpc_getattr(0.0, ino=ino, lease_epoch=epoch - 1)
    assert ei.value.errno == Errno.ESTALE
    assert s.stats.get("lease_stale", 0) >= 1
    cl.close()


def test_migration_handoff_bumps_epoch_and_drops_client_lease(workdir):
    """A migrated-in inode gets a fresh epoch at the receiver, and the
    client-side lease dies with the ownership change (epochs on different
    owners are not comparable)."""
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl, consistency="weak")
    fs.write_file("/b/m.bin", b"z" * 64)
    root_b = fs.resolve("/b")
    fs.listdir("/b")
    assert fs.client._lease_for(root_b) is not None
    old_owner = fs.client.ring.node_for(meta_key(root_b))
    cl.add_node()
    fs.client._pull_node_list()
    new_owner = fs.client.ring.node_for(meta_key(root_b))
    if new_owner != old_owner:
        # ownership moved: the lease must be gone and the receiver must hold
        # a bumped epoch (directories always migrate)
        assert fs.client._lease_for(root_b) is None
        assert cl.servers[new_owner].state.lease_epoch(root_b) >= 1
    # correctness either way: listing still works against the new ring
    assert "m.bin" in fs.listdir("/b")
    cl.close()


def test_fastpaths_preserve_semantics_on_shared_trace(workdir):
    """Metamorphic check over the open-loop harness: replaying the same
    trace with the metadata fast paths (leases + batching) on vs off must
    reach the identical filesystem end-state — the fast paths may only
    change *how many* envelopes cross the wire, never what the ops do."""
    import os

    def replay(sub, fast):
        os.makedirs(sub)
        cl = make_cluster(sub, n=2, chunk=64 * 1024)
        try:
            if not fast:
                cl.cfg.lease_ttl_s = 0.0
                cl.cfg.batch_rpcs = False
            boot = make_fs(cl, consistency="strict")
            boot.client.client_id = 9001
            boot.makedirs("/bench/a")
            dirs, files = [], []
            for d in range(2):
                dp = f"/data{d}"
                boot.mkdir(dp)
                dirs.append(dp)
                for i in range(6):
                    p = f"{dp}/f{i}.bin"
                    boot.write_file(p, bytes(2048))
                    files.append(p)
            # metadata-heavy mix so lease hits and batchable lookups occur
            spec = TenantSpec(
                "a", PoissonArrivals(400), n_clients=8, write_bytes=2048,
                op_mix={"stat": 0.35, "listdir": 0.25, "read": 0.20,
                        "write": 0.15, "create": 0.05})
            sched = build_schedule([spec], files, dirs, horizon_s=0.5,
                                   seed=77)
            # small client pool: repeat metadata hits land on warm leases
            runner = OpenLoopRunner(cl, [spec], consistency="weak",
                                    pool_per_tenant=2)
            results = runner.run(sched)
            reader = make_fs(cl, consistency="strict")
            reader.client.client_id = 9002
            return ([(r.ev.t, r.status) for r in results],
                    fs_fingerprint(reader), cl.router.rpc_count)
        finally:
            cl.close()

    ops_on, fp_on, env_on = replay(os.path.join(workdir, "on"), fast=True)
    ops_off, fp_off, env_off = replay(os.path.join(workdir, "off"),
                                      fast=False)
    assert ops_on == ops_off            # every op succeeds/fails identically
    assert fp_on == fp_off              # identical tree, sizes, and content
    assert env_on < env_off             # strictly fewer wire envelopes


def test_lease_epochs_rederived_by_replay(workdir):
    """Epoch bumps live in the WAL apply path, so a restarted owner rejects
    stale leases exactly as before the crash."""
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency="weak")
    fs.write_file("/b/r1.bin", b"a" * 32)
    fs.write_file("/b/r2.bin", b"b" * 32)
    fs.rename("/b/r1.bin", "/b/r3.bin")
    node = cl.node_list()[0]
    before = dict(cl.servers[node].state.lease_epochs)
    cl.crash_node(node)
    cl.restart_node(node)
    assert cl.servers[node].state.lease_epochs == before
    cl.close()
