"""Baseline semantics: S3FS-like (sync upload on close, per-node cache)
and S3 direct (staging copies)."""

import numpy as np

from repro.baselines import S3Direct, S3FSConfig, S3FSLike
from repro.core import CosStore, HardwareModel, SimClock


def mk(bucket="b"):
    clock = SimClock()
    cos = CosStore(clock, HardwareModel())
    return clock, cos


def test_s3fs_uploads_synchronously_on_close():
    clock, cos = mk()
    s3fs = S3FSLike(cos, "b", clock)
    fh = s3fs.open("f.bin", "w")
    s3fs.write(fh, 0, b"DATA" * 1000)
    assert not cos.exists("b", "f.bin")     # buffered
    s3fs.close(fh)
    assert cos.exists("b", "f.bin")         # synchronous upload at close
    assert cos.get_object("b", "f.bin")[0] == b"DATA" * 1000


def test_s3fs_no_cross_node_sharing():
    """Two nodes each pay the COS fetch — the paper's §6.3 point."""
    clock, cos = mk()
    blob = bytes(np.random.default_rng(0).integers(0, 256, size=1 << 20,
                                                   dtype=np.uint8))
    cos.put_object("b", "m.bin", blob)
    n1 = S3FSLike(cos, "b", clock, node="n1")
    n2 = S3FSLike(cos, "b", clock, node="n2")
    assert n1.read_file("m.bin") == blob
    gets_after_n1 = cos.ops.get("get_object", 0)
    assert n2.read_file("m.bin") == blob
    assert cos.ops["get_object"] > gets_after_n1   # n2 re-fetched
    # but n1 again is a page-cache hit
    before = cos.ops["get_object"]
    assert n1.read_file("m.bin") == blob
    assert cos.ops["get_object"] == before


def test_s3fs_partial_update_downloads_full_object():
    clock, cos = mk()
    blob = b"A" * 200_000
    cos.put_object("b", "p.bin", blob)
    s3fs = S3FSLike(cos, "b", clock)
    fh = s3fs.open("p.bin", "r+")
    s3fs.write(fh, 100, b"ZZZ")
    s3fs.close(fh)
    got = cos.get_object("b", "p.bin")[0]
    assert got[:100] == blob[:100] and got[100:103] == b"ZZZ"


def test_s3direct_staging_roundtrip():
    clock, cos = mk()
    blob = bytes(np.random.default_rng(1).integers(0, 256, size=1 << 20,
                                                   dtype=np.uint8))
    cos.put_object("b", "w.bin", blob)
    s3 = S3Direct(cos, "b", clock)
    t0 = clock.now
    assert s3.download("w.bin") == blob
    t_download = clock.now - t0
    assert t_download > 0
    assert s3.read_local("w.bin") == blob   # extra staging read
    s3.upload("out.bin", blob)
    assert cos.get_object("b", "out.bin")[0] == blob
