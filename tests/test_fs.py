"""ObjcacheFS behaviour: POSIX ops, consistency models, lazy COS namespace,
partial overwrites, and a property-based random-IO oracle test."""

import numpy as np
import pytest

from proptest import given, settings, st

from repro.core import Errno, FSError, InodeKind
from conftest import CHUNK, make_cluster, make_fs


def test_lazy_namespace_from_cos(workdir):
    cl = make_cluster(workdir)
    cl.cos.put_object("b", "a/x.bin", b"X" * 100)
    cl.cos.put_object("b", "a/y.bin", b"Y" * 200)
    cl.cos.put_object("b", "top.bin", b"T")
    fs = make_fs(cl)
    assert fs.listdir("/b") == ["a", "top.bin"]
    assert fs.listdir("/b/a") == ["x.bin", "y.bin"]
    assert fs.stat("/b/a/y.bin")["size"] == 200
    assert fs.read_file("/b/a/x.bin") == b"X" * 100
    cl.close()


@pytest.mark.parametrize("consistency", ["strict", "weak"])
@pytest.mark.parametrize("deployment", ["detached", "embedded"])
def test_write_read_roundtrip_models(workdir, consistency, deployment):
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency=consistency, deployment=deployment)
    blob = bytes(np.random.default_rng(1).integers(
        0, 256, size=3 * CHUNK + 777, dtype=np.uint8))
    fs.write_file("/b/f.bin", blob)
    assert fs.read_file("/b/f.bin") == blob
    cl.close()


def test_read_after_write_cross_client_strict(workdir):
    """Strict: a second client sees writes immediately (no fsync/close)."""
    cl = make_cluster(workdir)
    fs1 = make_fs(cl, consistency="strict", node=cl.node_list()[0])
    fs2 = make_fs(cl, consistency="strict", node=cl.node_list()[1])
    fh1 = fs1.open("/b/shared.bin", "w")
    fs1.write(fh1, 0, b"hello world")
    fh2 = fs2.open("/b/shared.bin", "r")
    assert fs2.read(fh2, 0, 11) == b"hello world"
    fs1.write(fh1, 6, b"objch")
    assert fs2.read(fh2, 0, 11) == b"hello objch"
    fs1.close(fh1)
    fs2.close(fh2)
    cl.close()


def test_close_to_open_visibility_weak(workdir):
    """Weak: writes become visible to other clients at close; a reader that
    opened before may serve stale cached data until it re-opens."""
    cl = make_cluster(workdir)
    w = make_fs(cl, consistency="weak", node=cl.node_list()[0])
    r = make_fs(cl, consistency="weak", node=cl.node_list()[1])
    fh = w.open("/b/c2o.bin", "w")
    w.write(fh, 0, b"AAAA")
    w.close(fh)
    fh2 = r.open("/b/c2o.bin", "r")
    assert r.read(fh2, 0, 4) == b"AAAA"
    r.close(fh2)
    fh = w.open("/b/c2o.bin", "r+")
    w.write(fh, 0, b"BBBB")
    w.close(fh)
    # re-open sees the new content (close-to-open)
    fh3 = r.open("/b/c2o.bin", "r")
    assert r.read(fh3, 0, 4) == b"BBBB"
    r.close(fh3)
    cl.close()


def test_partial_overwrite_and_persist(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    blob = bytearray(b"z" * (2 * CHUNK + 100))
    fs.write_file("/b/p.bin", bytes(blob))
    fh = fs.open("/b/p.bin", "r+")
    fs.write(fh, CHUNK - 5, b"MARKER")     # crosses a chunk boundary
    fs.fsync(fh)
    fs.close(fh)
    blob[CHUNK - 5:CHUNK + 1] = b"MARKER"
    obj, _ = cl.cos.get_object("b", "p.bin")
    assert obj == bytes(blob)
    cl.close()


def test_truncate_and_grow(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    fs.write_file("/b/t.bin", b"0123456789")
    fs.truncate("/b/t.bin", 4)
    assert fs.read_file("/b/t.bin") == b"0123"
    fh = fs.open("/b/t.bin", "r+")
    fs.write(fh, 8, b"XY")                 # sparse hole is zero-filled
    fs.close(fh)
    assert fs.read_file("/b/t.bin") == b"0123\0\0\0\0XY"
    cl.close()


def test_unlink_propagates_delete_to_cos(workdir):
    cl = make_cluster(workdir)
    cl.cos.put_object("b", "dead.bin", b"D" * 100)
    fs = make_fs(cl)
    assert fs.read_file("/b/dead.bin") == b"D" * 100
    fs.unlink("/b/dead.bin")
    assert not fs.exists("/b/dead.bin")
    cl.drain_dirty()
    assert not cl.cos.exists("b", "dead.bin")
    cl.close()


def test_rename_rekeys_object(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    fs.write_file("/b/old.bin", b"CONTENT")
    fh = fs.open("/b/old.bin", "r+")
    fs.fsync(fh)
    fs.close(fh)
    assert cl.cos.exists("b", "old.bin")
    fs.rename("/b/old.bin", "/b/new.bin")
    assert fs.read_file("/b/new.bin") == b"CONTENT"
    assert not fs.exists("/b/old.bin")
    cl.drain_dirty()
    assert cl.cos.exists("b", "new.bin")
    assert not cl.cos.exists("b", "old.bin")   # old key deleted (§5.4)
    cl.close()


def test_mkdir_eexist_enoent_errors(workdir):
    cl = make_cluster(workdir)
    fs = make_fs(cl)
    fs.makedirs("/b/d1/d2")
    with pytest.raises(FSError) as ei:
        fs.mkdir("/b/d1")
    assert ei.value.errno == Errno.EEXIST
    with pytest.raises(FSError) as ei:
        fs.read_file("/b/d1/nope.bin")
    assert ei.value.errno == Errno.ENOENT
    with pytest.raises(FSError) as ei:
        fs.unlink("/b/d1")                  # non-empty dir
    assert ei.value.errno == Errno.ENOTEMPTY
    cl.close()


@given(st.lists(
    st.tuples(st.integers(0, 3 * CHUNK), st.integers(1, CHUNK // 2)),
    min_size=1, max_size=8),
    st.sampled_from(["strict", "weak"]))
@settings(max_examples=20, deadline=None)
def test_random_writes_match_oracle(tmp_path_factory, ops, consistency):
    workdir = str(tmp_path_factory.mktemp("oc"))
    cl = make_cluster(workdir)
    fs = make_fs(cl, consistency=consistency)
    rng = np.random.default_rng(0)
    oracle = bytearray()
    fh = fs.open("/b/r.bin", "w")
    for off, ln in ops:
        data = bytes(rng.integers(0, 256, size=ln, dtype=np.uint8))
        fs.write(fh, off, data)
        if len(oracle) < off + ln:
            oracle.extend(b"\0" * (off + ln - len(oracle)))
        oracle[off:off + ln] = data
    fs.close(fh)
    assert fs.read_file("/b/r.bin") == bytes(oracle)
    # persistence preserves the same bytes
    fh = fs.open("/b/r.bin", "r+")
    fs.fsync(fh)
    fs.close(fh)
    obj, _ = cl.cos.get_object("b", "r.bin")
    assert obj == bytes(oracle)
    cl.close()
