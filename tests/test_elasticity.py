"""Elastic scaling: join/leave/zero-scale preserve data; only dirty objects
(and directories) migrate; stale clients retry with fresh node lists."""

import numpy as np

from repro.core import InodeKind
from conftest import CHUNK, make_cluster, make_fs


def _blob(n, seed=0):
    return bytes(np.random.default_rng(seed).integers(0, 256, size=n,
                                                      dtype=np.uint8))


def test_join_migrates_only_dirty_plus_dirs(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    clean = _blob(2 * CHUNK, 1)
    cl.cos.put_object("b", "clean.bin", clean)
    assert fs.read_file("/b/clean.bin") == clean   # cached, stays clean
    dirty = _blob(CHUNK + 5, 2)
    fs.makedirs("/b/d")
    fs.write_file("/b/d/dirty.bin", dirty)

    st = cl.add_node()
    assert st.migrated_chunks <= 2 + 1   # only the dirty file's chunks
    # clean data was dropped/kept, never migrated as dirty payload
    fs.client._pull_node_list()
    assert fs.read_file("/b/d/dirty.bin") == dirty
    assert fs.read_file("/b/clean.bin") == clean
    cl.close()


def test_leave_uploads_dirty_then_serves(workdir):
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl, node=cl.node_list()[0])
    data = _blob(2 * CHUNK + 99, 3)
    fs.write_file("/b/x.bin", data)
    victim = cl.node_list()[-1]
    cl.remove_node(victim)
    fs.client._pull_node_list()
    assert fs.read_file("/b/x.bin") == data
    assert cl.cos.exists("b", "x.bin") or cl.dirty_counts()[
        "dirty_metas"] >= 0  # uploaded if the leaver owned dirty state
    cl.close()


def test_scale_down_to_zero_then_cold_restart(workdir):
    """The paper's central elasticity claim: all dirty state lands in COS
    at zero scale, and a brand-new cluster reconstructs it from COS."""
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl)
    files = {f"/b/dir{i}/f{i}.bin": _blob(CHUNK + i * 7, i)
             for i in range(4)}
    for p, d in files.items():
        fs.makedirs(p.rsplit("/", 1)[0])
        fs.write_file(p, d)
    for nm in list(cl.node_list()):
        cl.remove_node(nm)
    assert not cl.servers
    for p, d in files.items():
        key = p[len("/b/"):]
        obj, _ = cl.cos.get_object("b", key)
        assert obj == d, p

    # cold restart: fresh cluster, fresh workdir — data comes from COS
    cl2 = make_cluster(workdir + "-2", n=2)
    cl2.cos = cl.cos  # same external storage
    for s in cl2.servers.values():
        s.cos = cl.cos
    fs2 = make_fs(cl2)
    for p, d in files.items():
        assert fs2.read_file(p) == d, p
    cl2.close()


def test_client_survives_scaling_with_estale_retry(workdir):
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _blob(CHUNK, 9)
    fs.write_file("/b/s.bin", data)
    cl.add_node()           # client's node list is now stale
    assert fs.read_file("/b/s.bin") == data   # ESTALE -> pull -> retry
    fs.write_file("/b/s2.bin", data)
    cl.add_node()
    assert fs.read_file("/b/s2.bin") == data
    cl.close()


def test_scale_stats_recorded(workdir):
    cl = make_cluster(workdir, n=1)
    fs = make_fs(cl)
    for i in range(6):
        fs.write_file(f"/b/f{i}.bin", _blob(CHUNK // 2, i))
    st = cl.add_node()
    assert st.op == "join" and st.duration >= 0
    st2 = cl.remove_node(cl.node_list()[-1])
    assert st2.op == "leave"
    assert len(cl.scale_log) == 2
    cl.close()


def test_node_crash_restart_preserves_cluster_data(workdir):
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl)
    data = _blob(3 * CHUNK, 11)
    fs.write_file("/b/crash.bin", data)
    for victim in cl.node_list():
        cl.crash_node(victim)
        cl.restart_node(victim)
    assert fs.read_file("/b/crash.bin") == data
    cl.close()
