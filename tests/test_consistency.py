"""§4.4 ordering/atomicity of racy writes, and §2.1 file-as-IPC semantics.

"if a client updates an inode with chunks Ca1 and Ca2, and another client
updates the same inode with chunks Cb1 and Cb2 at the same offset ...
readers should observe the inode with either Ca1-Ca2 or Cb1-Cb2" — never a
mix."""

import numpy as np
import pytest

from repro.core import Errno, FSError
from conftest import CHUNK, make_cluster, make_fs


def test_racy_cross_chunk_writes_are_atomic(workdir):
    """Interleave two clients' staged writes over the same chunk-crossing
    region; whichever flush commits later must win for the WHOLE region."""
    cl = make_cluster(workdir, n=3)
    w1 = make_fs(cl, consistency="strict", node=cl.node_list()[0])
    w2 = make_fs(cl, consistency="strict", node=cl.node_list()[1])
    base = bytes(CHUNK * 2)
    w1.write_file("/b/race.bin", base)

    region_off = CHUNK - 100       # crosses the chunk boundary
    region_len = 200
    pat_a = b"A" * region_len
    pat_b = b"B" * region_len

    # stage+flush through the public API in interleaved order: client 1
    # writes A, client 2 writes B after — the transaction protocol must
    # leave the entire region as B (the later committed transaction)
    fh1 = w1.open("/b/race.bin", "r+")
    fh2 = w2.open("/b/race.bin", "r+")
    w1.write(fh1, region_off, pat_a)
    w2.write(fh2, region_off, pat_b)
    w1.close(fh1)
    w2.close(fh2)

    reader = make_fs(cl, consistency="strict", node=cl.node_list()[2])
    got = reader.read_file("/b/race.bin")[region_off:region_off + region_len]
    assert got in (pat_a, pat_b), got[:32]
    assert got == pat_b             # later commit wins, atomically
    cl.close()


def test_interleaved_staging_still_atomic(workdir):
    """Stage both clients' chunk payloads BEFORE either flush commits: the
    client API serializes at the flush transaction, so the region is never
    half-A half-B regardless of staging order."""
    cl = make_cluster(workdir, n=3)
    w1 = make_fs(cl, consistency="strict", node=cl.node_list()[0])
    w2 = make_fs(cl, consistency="strict", node=cl.node_list()[1])
    w1.write_file("/b/r2.bin", bytes(CHUNK * 2))
    region_off, region_len = CHUNK - 64, 128
    ino = w1.resolve("/b/r2.bin")

    # drive the client internals directly: stage A and B, then flush B, A
    c1, c2 = w1.client, w2.client
    seq1, seq2 = c1.next_seq(), c2.next_seq()
    staged1 = c1.write_chunks(ino, region_off, b"A" * region_len, seq1)
    staged2 = c2.write_chunks(ino, region_off, b"B" * region_len, seq2)
    c2.flush_write(ino, staged2, CHUNK * 2, seq2)
    c1.flush_write(ino, staged1, CHUNK * 2, seq1)

    reader = make_fs(cl, consistency="strict", node=cl.node_list()[2])
    got = reader.read_file("/b/r2.bin")[region_off:region_off + region_len]
    assert got in (b"A" * region_len, b"B" * region_len), got[:32]
    assert got == b"A" * region_len   # flushed last -> wins whole-region
    cl.close()


def test_file_as_ipc_between_processes(workdir):
    """§2.1: strict consistency lets distributed jobs use files for IPC
    'as if processes in a cluster were in the same physical node'."""
    cl = make_cluster(workdir, n=2)
    producer = make_fs(cl, consistency="strict", node=cl.node_list()[0])
    consumer = make_fs(cl, consistency="strict", node=cl.node_list()[1])

    producer.makedirs("/b/jobs")
    producer.write_file("/b/jobs/task0.req", b"payload-0")
    # consumer polls the directory (common shell-script pattern)
    names = consumer.listdir("/b/jobs")
    assert names == ["task0.req"]
    req = consumer.read_file("/b/jobs/task0.req")
    consumer.write_file("/b/jobs/task0.done", req.upper())
    # producer immediately observes the response (read-after-write)
    assert producer.read_file("/b/jobs/task0.done") == b"PAYLOAD-0"
    producer.unlink("/b/jobs/task0.req")
    with pytest.raises(FSError):
        consumer.read_file("/b/jobs/task0.req")
    cl.close()


def test_write_visibility_requires_commit_not_stage(workdir):
    """Staged-but-unflushed chunk data must be invisible (§5.3: outstanding
    writes are separate from the committed chunk version)."""
    cl = make_cluster(workdir, n=2)
    w = make_fs(cl, consistency="strict", node=cl.node_list()[0])
    r = make_fs(cl, consistency="strict", node=cl.node_list()[1])
    w.write_file("/b/v.bin", b"x" * 256)
    ino = w.resolve("/b/v.bin")
    seq = w.client.next_seq()
    w.client.write_chunks(ino, 0, b"y" * 256, seq)   # staged only
    assert r.read_file("/b/v.bin") == b"x" * 256      # not visible
    w.client.flush_write(ino, [(0, [f"{w.client.client_id}.{seq}.0"])],
                         256, seq)
    assert r.read_file("/b/v.bin") == b"y" * 256      # visible after commit
    cl.close()
