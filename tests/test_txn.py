"""2PC protocol: atomicity, lock conflicts, TxId dedup, crash recovery."""

import pytest

from repro.core import Cmd, Errno, FSError
from repro.core.server import NODELIST_KEY
from repro.core.types import meta_key
from conftest import make_cluster, make_fs


INO_A, INO_B = 7001, 7002


def _meta_op(ino, size):
    from repro.core import InodeKind, InodeMeta
    meta = InodeMeta(ino=ino, kind=InodeKind.FILE, size=size)
    return {"kind": "meta_put", "meta": meta.to_payload()}


def two_node_plan(cl, size):
    """A plan touching two distinct servers (dummy inode metadata)."""
    nodes = cl.node_list()
    return {
        nodes[0]: {"cmd": Cmd.TX_PREPARE_META, "ops": [_meta_op(INO_A, size)],
                   "keys": ["k0"]},
        nodes[1]: {"cmd": Cmd.TX_PREPARE_META, "ops": [_meta_op(INO_B, size)],
                   "keys": ["k1"]},
    }


def _applied(cl, size):
    nodes = cl.node_list()
    a = cl.servers[nodes[0]].metas.get(INO_A)
    b = cl.servers[nodes[1]].metas.get(INO_B)
    return a is not None and a.size == size \
        and b is not None and b.size == size


def test_commit_applies_on_all_participants(workdir):
    cl = make_cluster(workdir, n=3)
    coord = cl.servers[cl.node_list()[0]]
    plan = two_node_plan(cl, 111)
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1, plan=plan)
    assert res["outcome"] == "commit"
    assert _applied(cl, 111)
    cl.close()


def test_duplicate_request_replays_old_result(workdir):
    cl = make_cluster(workdir, n=3)
    coord = cl.servers[cl.node_list()[0]]
    plan = two_node_plan(cl, 42)
    res1, _ = coord.coord_execute(0.0, client_id=7, seq=5, plan=plan)
    res2, _ = coord.coord_execute(0.0, client_id=7, seq=5, plan=plan)
    assert res1["outcome"] == "commit"
    assert res2 == {"outcome": "commit", "dup": True}
    cl.close()


def test_lock_conflict_aborts(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    p1 = cl.servers[nodes[1]]
    # hold a lock on k1 via a dangling prepare from another tx
    p1.rpc_prepare(0.0, txid_p={"client_id": 9, "seq": 9, "txseq": 9},
                   cmd_id=int(Cmd.TX_PREPARE_META), ops=[], keys=["k1"])
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1,
                                 plan=two_node_plan(cl, 13))
    assert res["outcome"] == "abort"
    # no partial application anywhere (atomicity)
    assert not _applied(cl, 13)
    assert cl.servers[nodes[0]].metas.get(INO_A) is None
    # after the blocker aborts, the client's retry (same client_id/seq, as
    # the FUSE client re-issues the same op) claims the hand-off and commits
    p1.rpc_abort(0.0, txid_p={"client_id": 9, "seq": 9, "txseq": 9})
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1,
                                 plan=two_node_plan(cl, 13))
    assert res["outcome"] == "commit"
    assert _applied(cl, 13)
    cl.close()


def test_participant_crash_before_prepare_aborts(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    cl.servers[nodes[1]].crash()
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1,
                                 plan=two_node_plan(cl, 77))
    assert res["outcome"] == "abort"
    # survivor must not have applied
    assert cl.servers[nodes[0]].metas.get(INO_A) is None
    cl.close()


def test_participant_crash_after_prepare_recovers_locks(workdir):
    """Prepared-but-undecided state must survive replay: the participant
    re-acquires its locks so the coordinator's eventual decision applies."""
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    p1 = cl.servers[nodes[1]]
    p1.rpc_prepare(0.0, txid_p={"client_id": 3, "seq": 1, "txseq": 4},
                   cmd_id=int(Cmd.TX_PREPARE_META),
                   ops=[_meta_op(INO_B, 55)],
                   keys=["kk"])
    p1.crash()
    cl.restart_node(nodes[1])
    p1 = cl.servers[nodes[1]]
    assert p1.locks.holder("kk") is not None
    assert p1.metas.get(INO_B) is None     # prepared, not applied
    # commit after recovery applies the redo
    p1.rpc_commit(0.0, txid_p={"client_id": 3, "seq": 1, "txseq": 4})
    assert p1.metas.get(INO_B).size == 55
    cl.close()


def test_coordinator_crash_after_decide_redrives_commit(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    coord.arm_crash("coord_after_decide")
    from repro.core.net import SimCrash
    with pytest.raises(SimCrash):
        coord.coord_execute(0.0, client_id=7, seq=1,
                            plan=two_node_plan(cl, 88))
    # participants are prepared and blocked; coordinator restart re-drives
    cl.restart_node(nodes[0])
    assert _applied(cl, 88)
    cl.close()


def test_coordinator_crash_before_decide_aborts_on_recovery(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    coord.arm_crash("coord_after_begin")
    from repro.core.net import SimCrash
    with pytest.raises(SimCrash):
        coord.coord_execute(0.0, client_id=7, seq=1,
                            plan=two_node_plan(cl, 99))
    cl.restart_node(nodes[0])
    # undecided -> abort; nothing applied, locks free
    assert not _applied(cl, 99)
    for nm in nodes[:2]:
        assert cl.servers[nm].locks.held_count() == 0
    cl.close()


def test_single_node_fast_path_skips_2pc(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    s = cl.servers[nodes[0]]
    before = s.stats.get("tx_commit", 0)
    plan = {nodes[0]: {"cmd": Cmd.TX_PREPARE_META,
                       "ops": [_meta_op(INO_A, 5)],
                       "keys": ["solo"]}}
    res, _ = s.coord_execute(0.0, client_id=7, seq=1, plan=plan)
    assert res["outcome"] == "commit"
    assert s.stats.get("tx_local", 0) == 1
    assert s.stats.get("tx_commit", 0) == before  # no 2PC records
    cl.close()


# =========================================================================
# wait-die lock queueing (bounded FIFO queues + reservation hand-off)
# =========================================================================
def test_waitdie_older_queues_younger_dies():
    from repro.core.txn import LockTable
    from repro.core.types import TxId
    lt = LockTable(queue_depth=4)
    holder = TxId(1, 5, 5)
    assert lt.acquire(["k"], holder, now=0.0) == "granted"
    older = TxId(1, 3, 3)      # lower seq = older under wait-die ordering
    younger = TxId(1, 9, 9)
    assert lt.acquire(["k"], older, now=0.0) == "queued"
    assert lt.acquire(["k"], younger, now=0.0) == "die"
    assert lt.queued("k") == [older]


def test_waitdie_release_hands_off_to_oldest_waiter():
    from repro.core.txn import LockTable
    from repro.core.types import TxId
    lt = LockTable(queue_depth=4, reservation_ttl_s=1.0)
    holder, w1, w2 = TxId(1, 5, 5), TxId(1, 2, 2), TxId(1, 3, 3)
    lt.acquire(["k"], holder, now=0.0)
    assert lt.acquire(["k"], w1, now=0.0) == "queued"
    assert lt.acquire(["k"], w2, now=0.0) == "queued"
    lt.release(holder, now=0.1)
    # FIFO: w1 enqueued first, so the lock transfers to w1 as a reservation
    assert lt.holder("k") == w1
    # w1's retry claims it in person
    assert lt.acquire(["k"], w1, now=0.2) == "granted"
    lt.release(w1, now=0.3)
    assert lt.holder("k") == w2


def test_waitdie_expired_reservation_is_stolen():
    from repro.core.txn import LockTable
    from repro.core.types import TxId
    lt = LockTable(queue_depth=4, reservation_ttl_s=0.5)
    holder, waiter, late = TxId(1, 5, 5), TxId(1, 2, 2), TxId(1, 7, 7)
    lt.acquire(["k"], holder, now=0.0)
    lt.acquire(["k"], waiter, now=0.0)
    lt.release(holder, now=0.1)            # reserved for waiter until 0.6
    assert lt.acquire(["k"], late, now=0.2) == "die"   # reservation holds
    assert lt.acquire(["k"], late, now=0.7) == "granted"  # abandoned: stolen
    lt.release(late, now=0.8)


def test_waitdie_bounded_queue_dies_when_full():
    from repro.core.txn import LockTable
    from repro.core.types import TxId
    lt = LockTable(queue_depth=2)
    lt.acquire(["k"], TxId(1, 50, 50), now=0.0)
    assert lt.acquire(["k"], TxId(1, 10, 10), now=0.0) == "queued"
    assert lt.acquire(["k"], TxId(1, 11, 11), now=0.0) == "queued"
    assert lt.acquire(["k"], TxId(1, 12, 12), now=0.0) == "die"
    assert lt.queued_count() == 2


def test_voteno_mode_never_queues(workdir):
    cl = make_cluster(workdir)
    cl.cfg.lock_mode = "voteno"
    nodes = cl.node_list()
    p1 = cl.servers[nodes[1]]
    p1.rpc_prepare(0.0, txid_p={"client_id": 9, "seq": 9, "txseq": 9},
                   cmd_id=int(Cmd.TX_PREPARE_META), ops=[], keys=["k1"])
    res, _ = p1.rpc_prepare(0.0,
                            txid_p={"client_id": 1, "seq": 1, "txseq": 1},
                            cmd_id=int(Cmd.TX_PREPARE_META), ops=[],
                            keys=["k1"])
    assert res == {"vote": False, "why": "die"}
    assert p1.locks.queued_count() == 0
    cl.close()


def test_waitdie_prepare_vote_carries_verdict(workdir):
    """An older conflicting prepare votes no with why="queued" and keeps its
    place; the blocker's abort hands the lock over, so the *same operation*
    (same client_id/seq, fresh txseq) retried by the coordinator commits."""
    cl = make_cluster(workdir)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    p1 = cl.servers[nodes[1]]
    p1.rpc_prepare(0.0, txid_p={"client_id": 9, "seq": 9, "txseq": 9},
                   cmd_id=int(Cmd.TX_PREPARE_META), ops=[], keys=["k1"])
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1,
                                 plan=two_node_plan(cl, 13))
    assert res == {"outcome": "abort", "why": "queued"}
    # the abort decision must NOT evict the queued (never-prepared) waiter
    assert p1.locks.queued_count() == 1
    p1.rpc_abort(0.0, txid_p={"client_id": 9, "seq": 9, "txseq": 9})
    # hand-off: the released lock is reserved for the queued operation, and
    # the client's retry reuses (client_id, seq) so it claims the reservation
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1,
                                 plan=two_node_plan(cl, 13))
    assert res["outcome"] == "commit"
    assert _applied(cl, 13)
    cl.close()


def test_waitdie_crash_mid_queue_replay_rebuilds_holders_only(workdir):
    """Queued waiters are un-logged by design (they never prepared): replay
    reconstructs the holder's lock, leaves the queue empty, and the waiter's
    coordinator re-enqueues on retry with the same TxId."""
    cl = make_cluster(workdir)
    nodes = cl.node_list()
    p1 = cl.servers[nodes[1]]
    holder_p = {"client_id": 9, "seq": 9, "txseq": 9}
    p1.rpc_prepare(0.0, txid_p=holder_p,
                   cmd_id=int(Cmd.TX_PREPARE_META),
                   ops=[_meta_op(INO_B, 55)], keys=["k1"])
    # an older transaction queues behind the prepared holder
    res, _ = p1.rpc_prepare(0.0,
                            txid_p={"client_id": 7, "seq": 1, "txseq": 2},
                            cmd_id=int(Cmd.TX_PREPARE_META), ops=[],
                            keys=["k1"])
    assert res == {"vote": False, "why": "queued"}
    assert p1.locks.queued_count() == 1
    p1.crash()
    cl.restart_node(nodes[1])
    p1 = cl.servers[nodes[1]]
    # holder re-derived from the WAL, queue empty
    assert p1.locks.holder("k1") is not None
    assert p1.locks.queued_count() == 0
    # the waiter's retry re-enqueues; after the holder commits it proceeds
    res, _ = p1.rpc_prepare(0.0,
                            txid_p={"client_id": 7, "seq": 1, "txseq": 2},
                            cmd_id=int(Cmd.TX_PREPARE_META), ops=[],
                            keys=["k1"])
    assert res == {"vote": False, "why": "queued"}
    p1.rpc_commit(0.0, txid_p=holder_p)
    res, _ = p1.rpc_prepare(0.1,
                            txid_p={"client_id": 7, "seq": 1, "txseq": 2},
                            cmd_id=int(Cmd.TX_PREPARE_META), ops=[],
                            keys=["k1"])
    assert res["vote"] is True
    cl.close()
