"""2PC protocol: atomicity, lock conflicts, TxId dedup, crash recovery."""

import pytest

from repro.core import Cmd, Errno, FSError
from repro.core.server import NODELIST_KEY
from repro.core.types import meta_key
from conftest import make_cluster, make_fs


INO_A, INO_B = 7001, 7002


def _meta_op(ino, size):
    from repro.core import InodeKind, InodeMeta
    meta = InodeMeta(ino=ino, kind=InodeKind.FILE, size=size)
    return {"kind": "meta_put", "meta": meta.to_payload()}


def two_node_plan(cl, size):
    """A plan touching two distinct servers (dummy inode metadata)."""
    nodes = cl.node_list()
    return {
        nodes[0]: {"cmd": Cmd.TX_PREPARE_META, "ops": [_meta_op(INO_A, size)],
                   "keys": ["k0"]},
        nodes[1]: {"cmd": Cmd.TX_PREPARE_META, "ops": [_meta_op(INO_B, size)],
                   "keys": ["k1"]},
    }


def _applied(cl, size):
    nodes = cl.node_list()
    a = cl.servers[nodes[0]].metas.get(INO_A)
    b = cl.servers[nodes[1]].metas.get(INO_B)
    return a is not None and a.size == size \
        and b is not None and b.size == size


def test_commit_applies_on_all_participants(workdir):
    cl = make_cluster(workdir, n=3)
    coord = cl.servers[cl.node_list()[0]]
    plan = two_node_plan(cl, 111)
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1, plan=plan)
    assert res["outcome"] == "commit"
    assert _applied(cl, 111)
    cl.close()


def test_duplicate_request_replays_old_result(workdir):
    cl = make_cluster(workdir, n=3)
    coord = cl.servers[cl.node_list()[0]]
    plan = two_node_plan(cl, 42)
    res1, _ = coord.coord_execute(0.0, client_id=7, seq=5, plan=plan)
    res2, _ = coord.coord_execute(0.0, client_id=7, seq=5, plan=plan)
    assert res1["outcome"] == "commit"
    assert res2 == {"outcome": "commit", "dup": True}
    cl.close()


def test_lock_conflict_aborts(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    p1 = cl.servers[nodes[1]]
    # hold a lock on k1 via a dangling prepare from another tx
    p1.rpc_prepare(0.0, txid_p={"client_id": 9, "seq": 9, "txseq": 9},
                   cmd_id=int(Cmd.TX_PREPARE_META), ops=[], keys=["k1"])
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1,
                                 plan=two_node_plan(cl, 13))
    assert res["outcome"] == "abort"
    # no partial application anywhere (atomicity)
    assert not _applied(cl, 13)
    assert cl.servers[nodes[0]].metas.get(INO_A) is None
    # after the blocker aborts, a retry with a fresh seq commits
    p1.rpc_abort(0.0, txid_p={"client_id": 9, "seq": 9, "txseq": 9})
    res, _ = coord.coord_execute(0.0, client_id=7, seq=2,
                                 plan=two_node_plan(cl, 13))
    assert res["outcome"] == "commit"
    assert _applied(cl, 13)
    cl.close()


def test_participant_crash_before_prepare_aborts(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    cl.servers[nodes[1]].crash()
    res, _ = coord.coord_execute(0.0, client_id=7, seq=1,
                                 plan=two_node_plan(cl, 77))
    assert res["outcome"] == "abort"
    # survivor must not have applied
    assert cl.servers[nodes[0]].metas.get(INO_A) is None
    cl.close()


def test_participant_crash_after_prepare_recovers_locks(workdir):
    """Prepared-but-undecided state must survive replay: the participant
    re-acquires its locks so the coordinator's eventual decision applies."""
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    p1 = cl.servers[nodes[1]]
    p1.rpc_prepare(0.0, txid_p={"client_id": 3, "seq": 1, "txseq": 4},
                   cmd_id=int(Cmd.TX_PREPARE_META),
                   ops=[_meta_op(INO_B, 55)],
                   keys=["kk"])
    p1.crash()
    cl.restart_node(nodes[1])
    p1 = cl.servers[nodes[1]]
    assert p1.locks.holder("kk") is not None
    assert p1.metas.get(INO_B) is None     # prepared, not applied
    # commit after recovery applies the redo
    p1.rpc_commit(0.0, txid_p={"client_id": 3, "seq": 1, "txseq": 4})
    assert p1.metas.get(INO_B).size == 55
    cl.close()


def test_coordinator_crash_after_decide_redrives_commit(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    coord.arm_crash("coord_after_decide")
    from repro.core.net import SimCrash
    with pytest.raises(SimCrash):
        coord.coord_execute(0.0, client_id=7, seq=1,
                            plan=two_node_plan(cl, 88))
    # participants are prepared and blocked; coordinator restart re-drives
    cl.restart_node(nodes[0])
    assert _applied(cl, 88)
    cl.close()


def test_coordinator_crash_before_decide_aborts_on_recovery(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    coord = cl.servers[nodes[0]]
    coord.arm_crash("coord_after_begin")
    from repro.core.net import SimCrash
    with pytest.raises(SimCrash):
        coord.coord_execute(0.0, client_id=7, seq=1,
                            plan=two_node_plan(cl, 99))
    cl.restart_node(nodes[0])
    # undecided -> abort; nothing applied, locks free
    assert not _applied(cl, 99)
    for nm in nodes[:2]:
        assert cl.servers[nm].locks.held_count() == 0
    cl.close()


def test_single_node_fast_path_skips_2pc(workdir):
    cl = make_cluster(workdir, n=3)
    nodes = cl.node_list()
    s = cl.servers[nodes[0]]
    before = s.stats.get("tx_commit", 0)
    plan = {nodes[0]: {"cmd": Cmd.TX_PREPARE_META,
                       "ops": [_meta_op(INO_A, 5)],
                       "keys": ["solo"]}}
    res, _ = s.coord_execute(0.0, client_id=7, seq=1, plan=plan)
    assert res["outcome"] == "commit"
    assert s.stats.get("tx_local", 0) == 1
    assert s.stats.get("tx_commit", 0) == before  # no 2PC records
    cl.close()
