"""QoS admission control at the RPC fabric: token-bucket math, typed shed
errors, overload protection, and composition with write backpressure."""

import pytest

from conftest import make_cluster, make_fs
from repro.core import (AdmissionControl, AdmissionError, ClientConfig,
                        Errno, ObjcacheClient, ObjcacheFS, OnOffArrivals,
                        OpenLoopRunner, PoissonArrivals, ServerConfig,
                        TenantQos, TenantSpec, build_schedule, loadtest_hw,
                        summarize)


# =========================================================================
# GCRA token-bucket math, directly at simclock boundaries
# =========================================================================
def test_burst_drains_then_sheds():
    ac = AdmissionControl({"t": TenantQos(rate_ops_s=100, burst=4,
                                          queue_depth=0)})
    for _ in range(4):
        assert ac.decide("t", 0.0) == ("admit", 0.0)
    verdict, wait = ac.decide("t", 0.0)
    assert verdict == "shed"
    assert wait > 0.0


def test_refill_is_exact_at_rate_boundary():
    """After a drained burst the next token is available at exactly 1/rate
    of virtual time — no drift from repeated float accumulation."""
    rate, burst = 100.0, 4
    inc = 1.0 / rate
    ac = AdmissionControl({"t": TenantQos(rate_ops_s=rate, burst=burst,
                                          queue_depth=0)})
    for _ in range(burst):
        assert ac.decide("t", 0.0)[0] == "admit"
    # a hair before the boundary: still shed
    assert ac.decide("t", inc * 0.999)[0] == "shed"
    # at the boundary: exactly one token
    assert ac.decide("t", inc)[0] == "admit"
    assert ac.decide("t", inc)[0] == "shed"
    # steady state: one admit per 1/rate tick, forever conforming
    for k in range(2, 50):
        assert ac.decide("t", k * inc)[0] == "admit"


def test_idle_credit_caps_at_burst():
    ac = AdmissionControl({"t": TenantQos(rate_ops_s=100, burst=4,
                                          queue_depth=0)})
    for _ in range(4):
        assert ac.decide("t", 1000.0)[0] == "admit"
    # a long idle period refills at most `burst` tokens, not rate * idle
    assert ac.decide("t", 1000.0)[0] == "shed"


def test_delay_queue_bounds_then_shed():
    rate, burst, depth = 100.0, 1, 3
    inc = 1.0 / rate
    ac = AdmissionControl({"t": TenantQos(rate_ops_s=rate, burst=burst,
                                          queue_depth=depth)})
    assert ac.decide("t", 0.0) == ("admit", 0.0)
    waits = []
    for _ in range(depth):
        verdict, wait = ac.decide("t", 0.0)
        assert verdict == "delay"
        waits.append(wait)
    # each queued envelope waits one more token interval than the last
    assert waits == pytest.approx([inc, 2 * inc, 3 * inc])
    verdict, wait = ac.decide("t", 0.0)
    assert verdict == "shed"
    # the shed did not consume a token: the queue drains as scheduled and
    # at t = 4/rate there is exactly one fresh token again
    assert ac.decide("t", 4 * inc)[0] == "admit"
    assert ac.decide("t", 4 * inc)[0] == "delay"


def test_unpoliced_tenant_always_admitted():
    ac = AdmissionControl({"t": TenantQos(rate_ops_s=1, burst=1,
                                          queue_depth=0)})
    for _ in range(100):
        assert ac.decide("other", 0.0) == ("admit", 0.0)


# =========================================================================
# fabric integration: typed errors, stats, no-policy behavior
# =========================================================================
def _tagged_fs(cl, tenant, client_id=9100):
    client = ObjcacheClient(
        cl.router, cl.clock, cl.node_list()[0],
        ClientConfig(consistency="strict", tenant=tenant),
        chunk_size=cl.cfg.chunk_size, client_id=client_id)
    return ObjcacheFS(client)


def test_shed_surfaces_as_typed_admission_error(cluster):
    fs = _tagged_fs(cluster, "busy")
    fs.makedirs("/bench/busy")
    cluster.router.set_admission(
        {"busy": TenantQos(rate_ops_s=10, burst=1, queue_depth=0)})
    with pytest.raises(AdmissionError) as ei:
        for i in range(50):
            fs.stat("/bench/busy")
    err = ei.value
    assert err.errno == Errno.EAGAIN
    assert err.tenant == "busy"
    assert err.retry_after_s > 0.0
    assert err.method
    st = cluster.router.tenant_stats["busy"]
    assert st["shed"] >= 1
    assert st["admitted"] >= 1


def test_untagged_and_no_policy_traffic_never_policed(cluster):
    fs = make_fs(cluster)                      # untagged client
    fs.makedirs("/bench/x")
    cluster.router.set_admission(
        {"busy": TenantQos(rate_ops_s=1, burst=1, queue_depth=0)})
    for _ in range(20):
        fs.stat("/bench/x")                    # never raises
    assert "busy" not in {k: v for k, v in cluster.router.tenant_stats.items()
                          if v["shed"]}
    # clearing the policy unpolices tagged clients too
    cluster.router.set_admission(None)
    tagged = _tagged_fs(cluster, "busy")
    for _ in range(20):
        tagged.stat("/bench/x")
    assert cluster.router.admission is None


def test_shed_tenant_can_still_pull_node_list(cluster):
    """Control-plane traffic is untagged: a fully shed tenant still learns
    the node list, so it can retry against the right owners later."""
    cluster.router.set_admission(
        {"busy": TenantQos(rate_ops_s=1e-6, burst=1, queue_depth=0)})
    client = ObjcacheClient(
        cluster.router, cluster.clock, cluster.node_list()[0],
        ClientConfig(consistency="strict", tenant="busy"),
        chunk_size=cluster.cfg.chunk_size, client_id=9101)
    client._pull_node_list()                   # must not raise
    assert client.node_list


# =========================================================================
# overload protection, end to end over the open-loop harness
# =========================================================================
def test_overload_protects_gold_tenant(workdir):
    """At ~2x overload the contracted tenant keeps its p99 within budget
    and is never shed; the best-effort tenant absorbs the overload as
    sheds.  Without admission, everyone collapses together."""
    def run(admission):
        import os
        sub = os.path.join(workdir, "adm" if admission else "raw")
        os.makedirs(sub)
        cl = make_cluster(sub, n=3, chunk=64 * 1024, hw=loadtest_hw())
        try:
            boot = _tagged_fs(cl, None, client_id=9001)
            dirs, files = [], []
            for d in range(4):
                dp = f"/data{d}"
                boot.mkdir(dp)
                dirs.append(dp)
                for i in range(8):
                    p = f"{dp}/f{i}.bin"
                    boot.write_file(p, bytes(4096))
                    files.append(p)
            for t in ("gold", "best"):
                boot.makedirs(f"/bench/{t}")
            tenants = [
                TenantSpec("gold", PoissonArrivals(250), n_clients=64,
                           write_bytes=4096, qos_class="gold"),
                TenantSpec("best", PoissonArrivals(750), n_clients=128,
                           write_bytes=4096, qos_class="best"),
            ]
            sched = build_schedule(tenants, files, dirs, horizon_s=1.0,
                                   seed=1234)
            if admission:
                cl.router.set_admission({
                    # ~4.7 envelopes per op; gold contracted over its offer,
                    # best clipped near 100 ops/s
                    "gold": TenantQos(rate_ops_s=1600, burst=64,
                                      queue_depth=64),
                    "best": TenantQos(rate_ops_s=500, burst=24,
                                      queue_depth=16),
                })
            runner = OpenLoopRunner(cl, tenants, consistency="strict",
                                    pool_per_tenant=8)
            return summarize(runner.run(sched), 1.0)
        finally:
            cl.close()

    raw = run(admission=False)
    adm = run(admission=True)
    gold, best = adm["tenants"]["gold"], adm["tenants"]["best"]
    assert gold["shed"] == 0
    assert best["shed_rate"] > 0.3
    # gold's p99 budget: bounded, and far below the collapsed no-admission
    # tail at the same offered load
    assert gold["p99_ms"] <= 150.0
    assert raw["tenants"]["gold"]["p99_ms"] > 2 * gold["p99_ms"]
    # shedding best-effort work must not starve it completely of goodput
    assert best["ok"] > 0


# =========================================================================
# composition with write backpressure (§5.2 bp_delay hints)
# =========================================================================
def _bp_cluster(workdir, chunk=64 * 1024):
    cfg = ServerConfig(chunk_size=chunk, dirty_hiwater_bytes=chunk,
                       dirty_lowater_bytes=chunk // 2)
    return make_cluster(workdir, n=2, chunk=chunk, cfg=cfg)


def test_bp_delay_stalls_untagged_client(workdir):
    """Control: with no admission in play, the bp_delay hint stalls the
    client for its full duration."""
    cl = _bp_cluster(workdir)
    try:
        fs = make_fs(cl)
        for i in range(8):
            fs.write_file(f"/f{i}.bin", bytes(96 * 1024))
        assert fs.client.stats.get("bp_stalls", 0) >= 1
        assert fs.client.stats.get("bp_stall_s", 0.0) > 0.0
    finally:
        cl.close()


def test_bp_delay_composes_with_admission_delay(workdir):
    """A tenant already delayed by admission during staging only stalls for
    the *remainder* of the backpressure hint — the two throttles compose
    instead of double-counting the same slowdown."""
    cl = _bp_cluster(workdir)
    try:
        # slow refill + deep queue: staging envelopes are delayed (never
        # shed), so every write carries admission delay into the bp window
        cl.router.set_admission(
            {"w": TenantQos(rate_ops_s=200, burst=2, queue_depth=4000)})
        fs = _tagged_fs(cl, "w", client_id=9102)
        for i in range(8):
            fs.write_file(f"/g{i}.bin", bytes(96 * 1024))
        st = cl.router.tenant_stats["w"]
        assert st["delayed"] >= 1
        assert st["delay_s"] > 0.0
        # the servers still issued backpressure hints...
        assert sum(s.stats.get("bp_stalls", 0)
                   for s in cl.servers.values()) >= 1
        # ...but the client's own stall time is smaller than the untagged
        # control's, because admission delay absorbed (part of) each hint
        control = ObjcacheFS(ObjcacheClient(
            cl.router, cl.clock, cl.node_list()[0],
            ClientConfig(consistency="strict"),
            chunk_size=cl.cfg.chunk_size, client_id=9103))
        for i in range(8):
            control.write_file(f"/h{i}.bin", bytes(96 * 1024))
        tagged_stall = fs.client.stats.get("bp_stall_s", 0.0)
        control_stall = control.client.stats.get("bp_stall_s", 0.0)
        assert control_stall > 0.0
        assert tagged_stall < control_stall
    finally:
        cl.close()
