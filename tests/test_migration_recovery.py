"""Refactored migration + dirty-eviction paths: ring change mid-dirty-write,
migrate_out → rpc_migrate_recv_* round-trips, and crash-at-injection-point
replay through the participant module."""

import numpy as np
import pytest

from repro.core import Cmd, HashRing, InodeKind
from repro.core.net import SimCrash
from repro.core.types import chunk_key, meta_key
from conftest import CHUNK, make_cluster, make_fs


def _blob(n, seed=0):
    return bytes(np.random.default_rng(seed).integers(0, 256, size=n,
                                                      dtype=np.uint8))


# =========================================================================
# ring change mid-dirty-write
# =========================================================================
def test_ring_change_mid_dirty_write(workdir):
    """A node joins while a file is dirty and its handle still open; the
    dirty state migrates, the client re-pulls the node list on ESTALE, and
    both the cache view and the eventual COS upload stay consistent."""
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    first = _blob(2 * CHUNK + 99, 1)
    fh = fs.open("/b/mid.bin", "w")
    fs.write(fh, 0, first)
    assert cl.dirty_counts()["dirty_metas"] >= 1

    st = cl.add_node()          # ring changes while the write is in flight
    assert st.op == "join"
    second = _blob(CHUNK, 2)
    fs.write(fh, len(first), second)      # continues after ESTALE re-pull
    fs.close(fh)

    assert fs.read_file("/b/mid.bin") == first + second
    cl.drain_dirty()
    assert cl.cos.get_object("b", "mid.bin")[0] == first + second
    # the file's chunks are clean again (dirs stay dirty until zero-scale)
    assert cl.dirty_counts()["dirty_chunks"] == 0
    cl.close()


# =========================================================================
# migrate_out → rpc_migrate_recv_* round-trip
# =========================================================================
def test_migrate_out_recv_roundtrip(workdir):
    """Drain one node via the migration subsystem directly: dirty metadata
    and chunks land on their new owners with bytes intact, directories always
    move, and the source evicts everything it sent or dropped."""
    cl = make_cluster(workdir, n=3)
    fs = make_fs(cl)
    fs.mkdir("/b/sub")
    data = _blob(2 * CHUNK + 7, 3)
    fs.write_file("/b/sub/f.bin", data)

    src_name = cl.node_list()[0]
    src = cl.servers[src_name]
    new_ring = HashRing([n for n in cl.node_list() if n != src_name])
    scan = src.migration_scan(new_ring)
    # every dir this node owns must be scheduled to move, never dropped
    owned_dirs = [ino for ino, m in src.metas.inodes.items()
                  if m.kind == InodeKind.DIR
                  and src.ring.node_for(meta_key(ino)) == src_name]
    assert sorted(ino for ino, _ in scan["dirs"]) == sorted(owned_dirs)

    moved, t = src.migrate_out(scan, cl.clock.now)
    cl.clock.advance_to(t)

    for ino, dst in scan["metas"] + scan["dirs"]:
        assert src.metas.get(ino) is None          # evicted at the source
        got = cl.servers[dst].metas.get(ino)
        assert got is not None and got.ino == ino  # landed at the new owner
    for (ino, coff), dst in scan["chunks"]:
        assert src.chunks.get(ino, coff) is None
        c = cl.servers[dst].chunks.get(ino, coff)
        assert c is not None and c.dirty
        assert new_ring.node_for(chunk_key(ino, coff)) == dst
    for ino in scan["drop_metas"]:
        assert src.metas.get(ino) is None
    for (ino, coff) in scan["drop_chunks"]:
        assert src.chunks.get(ino, coff) is None
    assert moved["dirs"] == len(scan["dirs"])
    assert moved["chunks"] == len(scan["chunks"])
    cl.close()


def test_migrate_recv_chunk_is_wal_durable(workdir):
    """A migrated-in chunk must survive a crash of the receiver: the
    MIGRATE_RECV_CHUNK record replays through the participant module."""
    cl = make_cluster(workdir, n=2)
    nodes = cl.node_list()
    payload = _blob(CHUNK // 2, 4)
    res, t = cl.router.rpc(nodes[0], nodes[1], "rpc_migrate_recv_chunk",
                           cl.clock.now, nbytes_out=len(payload) + 128,
                           ino=4242, chunk_off=0, version=3, dirty=True,
                           deleted=False, data=payload)
    assert res["ok"]
    cl.clock.advance_to(t)
    recv = cl.servers[nodes[1]]
    cl.crash_node(nodes[1])
    cl.restart_node(nodes[1])
    c = recv.chunks.get(4242, 0)
    assert c is not None and c.dirty and c.version == 3
    assert c.materialize(recv.raft, len(payload)) == payload
    cl.close()


def test_dirty_eviction_only_after_persist(workdir):
    """migration_scan drops clean objects (refetchable from COS) but keeps
    dirty ones; after a persist cycle the same objects become droppable."""
    cl = make_cluster(workdir, n=2)
    fs = make_fs(cl)
    data = _blob(CHUNK + 11, 5)
    fs.write_file("/b/e.bin", data)

    other = {n: cl.node_list()[1 - i] for i, n in enumerate(cl.node_list())}
    dirty_migrating = {
        nm: len(s.migration_scan(HashRing([other[nm]]))["metas"])
        + len(s.migration_scan(HashRing([other[nm]]))["chunks"])
        for nm, s in cl.servers.items()}
    assert sum(dirty_migrating.values()) >= 1   # dirty state must migrate

    cl.drain_dirty()                            # ... until it is persisted
    for nm, s in cl.servers.items():
        scan = s.migration_scan(HashRing([other[nm]]))
        assert scan["metas"] == [] and scan["chunks"] == []
    cl.close()


# =========================================================================
# crash-at-injection-point replay through the participant module
# =========================================================================
def _prepare(server, txid_seq, keys, ops):
    return server.rpc_prepare(
        0.0, txid_p={"client_id": 11, "seq": txid_seq, "txseq": txid_seq},
        cmd_id=int(Cmd.TX_PREPARE_META), ops=ops, keys=keys)


def test_crash_after_lock_before_prepare_leaves_no_lock(workdir):
    """participant_after_lock fires between lock acquisition and the WAL
    append: nothing was logged, so replay must NOT re-acquire the lock."""
    cl = make_cluster(workdir, n=2)
    p = cl.servers[cl.node_list()[1]]
    p.arm_crash("participant_after_lock")
    with pytest.raises(SimCrash):
        _prepare(p, 1, ["lk"], [])
    cl.restart_node(p.node_id)
    assert p.locks.holder("lk") is None
    # a fresh prepare for the same key now succeeds
    res, _ = _prepare(p, 2, ["lk"], [])
    assert res["vote"] is True
    cl.close()


def test_crash_after_prepare_replays_lock_and_redo(workdir):
    """participant_after_prepare fires after the WAL append: replay must
    re-acquire the lock and keep the redo image unapplied until commit."""
    from repro.core import InodeMeta
    cl = make_cluster(workdir, n=2)
    p = cl.servers[cl.node_list()[1]]
    meta = InodeMeta(ino=8808, kind=InodeKind.FILE, size=77)
    op = {"kind": "meta_put", "meta": meta.to_payload()}
    p.arm_crash("participant_after_prepare")
    with pytest.raises(SimCrash):
        _prepare(p, 1, ["pk"], [op])
    cl.restart_node(p.node_id)
    assert p.locks.holder("pk") is not None   # lock restored by replay
    assert p.metas.get(8808) is None          # prepared, not applied
    p.rpc_commit(0.0, txid_p={"client_id": 11, "seq": 1, "txseq": 1})
    assert p.metas.get(8808).size == 77
    assert p.locks.holder("pk") is None
    cl.close()


def test_crash_after_commit_dedups_on_retry(workdir):
    """participant_after_commit fires after the commit is logged: the apply
    survives replay and a retried commit answers from the dedup window."""
    from repro.core import InodeMeta
    cl = make_cluster(workdir, n=2)
    p = cl.servers[cl.node_list()[1]]
    meta = InodeMeta(ino=8809, kind=InodeKind.FILE, size=99)
    op = {"kind": "meta_put", "meta": meta.to_payload()}
    res, _ = _prepare(p, 1, ["ck"], [op])
    assert res["vote"] is True
    p.arm_crash("participant_after_commit")
    with pytest.raises(SimCrash):
        p.rpc_commit(0.0, txid_p={"client_id": 11, "seq": 1, "txseq": 1})
    cl.restart_node(p.node_id)
    assert p.metas.get(8809).size == 99       # commit applied via replay
    res, _ = p.rpc_commit(0.0, txid_p={"client_id": 11, "seq": 1, "txseq": 1})
    assert res == {"ok": True, "dup": True}
    cl.close()
