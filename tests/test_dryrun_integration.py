"""Integration: the multi-pod dry-run pipeline end-to-end for one fast cell
(subprocess — the 512-device XLA flag must precede jax init)."""

import json
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # JAX smoke: outside the tier-1 budget

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("cell", [("whisper-tiny", "train_4k"),
                                  ("mamba2-370m", "decode_32k")])
def test_dryrun_cell_compiles_and_reports(cell, tmp_path):
    arch, shape = cell
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=420)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "ALL CELLS PASSED" in proc.stdout
    rec_path = os.path.join(REPO, "reports", "dryrun",
                            f"{arch}__{shape}__16x16.json")
    rec = json.load(open(rec_path))
    pd = rec["per_device"]
    assert pd["flops"] > 0
    assert pd["bytes_accessed"] > 0
    assert rec["n_devices"] == 256          # 16x16 of the 512 placeholders
    # per-device memory must fit a 16 GB v5e
    assert pd["argument_bytes"] + pd["temp_bytes"] < 15.9 * 2**30, \
        (pd["argument_bytes"] / 2**30, pd["temp_bytes"] / 2**30)
