"""Pluggable storage backends + capacity-aware tiering (PR 10): profile
timing determinism, failure-profile retry schedules, coldest-first demotion
under capacity pressure, the dirty-durability-before-eviction invariant,
and metamorphic single-backend equivalence with the pre-tiering store."""

import numpy as np
import pytest

from repro.core import (BackendProfile, BucketMount, CosCapacityError,
                        CosError, CosStore, CosThrottleError, GcsStore,
                        HardwareModel, NvmeStore, SimClock, TierPolicy,
                        TieredStore, eviction_priority, fs_fingerprint)
from conftest import CHUNK, make_cluster, make_fs


def _blob(n, seed=0):
    return bytes(np.random.default_rng(seed).integers(0, 256, size=n,
                                                      dtype=np.uint8))


def _tier(clock, nvme_cap=4 << 20, policy=None):
    return TieredStore([NvmeStore(clock, capacity_bytes=nvme_cap),
                        CosStore(clock, HardwareModel())], clock, policy)


# ---------------------------------------------------------------------------
# backend profiles: timing determinism + failure envelopes
# ---------------------------------------------------------------------------

def test_backend_profiles_are_deterministic_and_distinct():
    """The same op sequence yields identical virtual end times on two
    identically-configured backends, and the three stock profiles order as
    expected (NVMe ≪ S3-like < GCS-like first-byte latency)."""
    def run_ops(be):
        ends = [be.put_object("b", f"k{i}", _blob(64 << 10, i), start=0.0)
                for i in range(4)]
        for i in range(4):
            _, e = be.get_object("b", f"k{i}", start=max(ends))
            ends.append(e)
        return ends

    a, b = CosStore(SimClock()), CosStore(SimClock())
    assert run_ops(a) == run_ops(b)

    ends = {}
    for cls in (CosStore, GcsStore, NvmeStore):
        be = cls(SimClock())
        ends[cls.__name__] = be.put_object("b", "k", _blob(256 << 10), start=0.0)
    assert ends["NvmeStore"] < ends["CosStore"]
    # GCS profile: higher first-byte latency + slow-start on early transfers
    assert ends["CosStore"] < ends["GcsStore"]


def test_gcs_slow_start_ramp_then_steady_state():
    gcs = GcsStore(SimClock())
    n = gcs.profile.slow_start_ops
    data = _blob(1 << 20)
    cold = [gcs.put_object("b", f"c{i}", data, start=float(i * 10))
            - i * 10 for i in range(n)]
    warm = gcs.put_object("b", "w", data, start=1e4) - 1e4
    assert all(c > warm for c in cold)
    assert gcs.stats["slow_starts"] == n


def test_throttle_every_retries_internally_then_surfaces():
    """With a retry budget the Nth request succeeds later (latency +
    backoff charged); with no budget it raises CosThrottleError."""
    p = BackendProfile(name="cos", throttle_every=3, max_retries=2)
    be = CosStore(SimClock(), profile=p)
    data = _blob(1 << 10)
    e1 = be.put_object("b", "k1", data, start=0.0)
    e2 = be.put_object("b", "k2", data, start=0.0)
    e3 = be.put_object("b", "k3", data, start=0.0)  # throttled + retried
    assert e3 == pytest.approx(
        e1 + p.latency_s + p.retry_backoff_s), "retry charges one RTT+backoff"
    assert e2 < e3
    assert be.stats["throttles"] == 1 and be.stats["retries"] == 1

    hard = CosStore(SimClock(),
                    profile=BackendProfile(throttle_every=2, max_retries=0))
    hard.put_object("b", "k1", data, start=0.0)
    with pytest.raises(CosThrottleError):
        hard.put_object("b", "k2", data, start=0.0)


def test_fail_next_is_one_shot():
    be = CosStore(SimClock())
    be.fail_next("put_object")
    with pytest.raises(CosError):
        be.put_object("b", "k", b"x", start=0.0)
    be.put_object("b", "k", b"x", start=0.0)  # next attempt succeeds


def test_nvme_capacity_rejects_before_mutating():
    nv = NvmeStore(SimClock(), capacity_bytes=1 << 20)
    nv.put_object("b", "a", _blob(768 << 10), start=0.0)
    with pytest.raises(CosCapacityError):
        nv.put_object("b", "big", _blob(512 << 10), start=0.0)
    assert nv.object_count() == 1 and not nv.exists("b", "big")
    # replacing an existing key only charges the delta
    nv.put_object("b", "a", _blob(1 << 20), start=0.0)
    assert nv.used_bytes() == 1 << 20


def test_put_limit_forces_mpu():
    be = CosStore(SimClock(),
                  profile=BackendProfile(put_limit_bytes=1 << 20))
    with pytest.raises(CosError):
        be.put_object("b", "big", _blob(2 << 20), start=0.0)
    uid, t = be.mpu_begin("b", "big", start=0.0)
    t = be.mpu_add(uid, 1, _blob(1 << 20), start=t)
    t = be.mpu_add(uid, 2, _blob(1 << 20, 1), start=t)
    be.mpu_commit(uid, start=t)
    assert be.exists("b", "big")


# ---------------------------------------------------------------------------
# tiering policy: promotion, demotion order, dirty durability
# ---------------------------------------------------------------------------

def test_promotion_on_read_heat():
    clock = SimClock()
    tier = _tier(clock, nvme_cap=8 << 20)
    tier.base.put_object("b", "hot", _blob(1 << 20), start=0.0)
    _, e1 = tier.get_object("b", "hot", start=1.0)     # base read, heat 1
    assert not tier.fast.exists("b", "hot")
    _, e2 = tier.get_object("b", "hot", start=e1)      # heat 2 -> promote
    assert tier.fast.exists("b", "hot")
    assert tier.counters["promotions"] == 1
    # the promotion fill is asynchronous: it must not extend the read
    assert e2 - e1 == pytest.approx(e1 - 1.0)
    _, e3 = tier.get_object("b", "hot", start=e2)      # NVMe hit
    assert e3 - e2 < (e2 - e1) / 10
    assert tier.counters["fast_hits"] == 1


def test_demotion_is_coldest_first_down_to_lowater():
    clock = SimClock()
    pol = TierPolicy(demote_hiwater=0.80, demote_lowater=0.45)
    tier = _tier(clock, nvme_cap=4 << 20, policy=pol)
    # four 900 KiB write-back puts with strictly increasing heat timestamps
    t = 0.0
    for i in range(4):
        t = tier.put_object("b", f"f{i}", _blob(900 << 10, i), start=t + 1.0)
    assert tier.under_pressure()
    moved, _ = tier.maintain(t)
    # must demote the two oldest-touched keys to fall to <= 45% of 4 MiB
    # (2 x 900 KiB residents = 43.9%)
    assert moved == 2
    assert not tier.fast.exists("b", "f0") and not tier.fast.exists("b", "f1")
    assert tier.fast.exists("b", "f2") and tier.fast.exists("b", "f3")
    # demoted keys are durable and still readable through the stack
    for k in ("f0", "f1"):
        assert tier.base.exists("b", k)
    assert not tier.under_pressure()


def test_eviction_priority_matches_flusher_rule():
    rows = [("cold-small", eviction_priority(1.0, 10, "a")),
            ("cold-big", eviction_priority(1.0, 99, "b")),
            ("hot", eviction_priority(9.0, 1000, "c"))]
    order = [name for name, key in sorted(rows, key=lambda r: r[1])]
    assert order == ["cold-big", "cold-small", "hot"]


def test_dirty_data_never_lost_on_eviction():
    """The invariant: a tier-dirty key forced out of the NVMe tier (room
    for a new put, watermark demotion, or flush_cache) is copied to the
    durable base *first* — no sequence of capacity events loses bytes."""
    clock = SimClock()
    tier = _tier(clock, nvme_cap=2 << 20)
    payloads = {f"f{i}": _blob(700 << 10, i) for i in range(6)}
    t = 0.0
    for k, v in payloads.items():       # 4.2 MB through a 2 MB tier
        t = tier.put_object("b", k, v, start=t + 1.0)
    assert tier.counters["room_demotions"] > 0
    t = tier.flush_cache(t)
    assert tier.tier_dirty_bytes() == 0
    for k, v in payloads.items():
        got, t = tier.get_object("b", k, start=t)
        assert got == v
        assert tier.base.exists("b", k)


def test_promotion_never_forces_dirty_demotion():
    """Room-making for a promotion only evicts *clean* residents: a tier
    full of dirty data simply skips the promotion."""
    clock = SimClock()
    tier = _tier(clock, nvme_cap=2 << 20)
    t = tier.put_object("b", "dirty", _blob(1800 << 10), start=0.0)
    t = tier.base.put_object("b", "warm", _blob(512 << 10), start=t)
    for _ in range(3):
        _, t = tier.get_object("b", "warm", start=t)
    assert not tier.fast.exists("b", "warm"), "promotion must be skipped"
    assert tier.fast.exists("b", "dirty") and tier.tier_dirty_bytes() > 0


def test_mpu_commit_invalidates_stale_cache_copy():
    clock = SimClock()
    tier = _tier(clock, nvme_cap=8 << 20)
    t = tier.put_object("b", "k", _blob(256 << 10, 1), start=0.0)  # cached
    assert tier.fast.exists("b", "k")
    uid, t = tier.mpu_begin("b", "k", start=t)
    t = tier.mpu_add(uid, 1, _blob(512 << 10, 2), start=t)
    t = tier.mpu_commit(uid, start=t)
    assert not tier.fast.exists("b", "k"), "stale cache copy must be dropped"
    got, _ = tier.get_object("b", "k", start=t)
    assert got == _blob(512 << 10, 2)


def test_writethrough_policy_bypasses_cache_tier():
    clock = SimClock()
    tier = _tier(clock, policy=TierPolicy(writeback=False))
    tier.put_object("b", "k", _blob(64 << 10), start=0.0)
    assert tier.base.exists("b", "k") and not tier.fast.exists("b", "k")
    assert tier.counters["writethrough_puts"] == 1
    assert tier.tier_dirty_bytes() == 0


# ---------------------------------------------------------------------------
# cluster integration: bucket->backend binding end to end
# ---------------------------------------------------------------------------

def test_cluster_tiered_mount_end_to_end(workdir):
    """Sub-chunk files through a tiered mount land tier-dirty on NVMe via
    the PutObject fast path; scale-to-zero demotes every dirty byte; a new
    cluster generation over the same backends reads everything back."""
    clock = SimClock()
    tier = _tier(clock, nvme_cap=32 << 20)
    cl = make_cluster(workdir + "/gen1", n=3,
                      buckets=[BucketMount("b", "b", backend="tiered")],
                      backends={"tiered": tier}, clock=clock)
    fs = make_fs(cl)
    files = {}
    for i in range(12):
        p, d = f"/b/f{i}.bin", _blob(100 << 10, i)  # sub-chunk: fast path
        fs.write_file(p, d)
        files[p] = d
    cl.drain_dirty(max_rounds=16)
    assert tier.counters["writeback_puts"] > 0, \
        "colocated sub-chunk persists must take the write-back fast path"
    cl.scale_to_zero()
    cl.close()
    assert tier.tier_dirty_bytes() == 0
    assert all(tier.base.exists("b", f"f{i}.bin") for i in range(12))

    cl2 = make_cluster(workdir + "/gen2", n=2,
                       buckets=[BucketMount("b", "b", backend="tiered")],
                       backends={"tiered": tier}, clock=clock)
    fs2 = make_fs(cl2)
    for p, d in files.items():
        assert fs2.read_file(p) == d
    cl2.close()


def test_flusher_tick_drives_tier_maintain(workdir):
    """The background flusher's tick runs the capacity-pressure pass on
    every registered backend with a `maintain` hook."""
    clock = SimClock()
    tier = _tier(clock, nvme_cap=2 << 20,
                 policy=TierPolicy(demote_hiwater=0.5, demote_lowater=0.25))
    cl = make_cluster(workdir, n=2,
                      buckets=[BucketMount("b", "b", backend="tiered")],
                      backends={"tiered": tier}, clock=clock)
    t = 0.0
    for i in range(3):
        t = tier.put_object("b", f"k{i}", _blob(512 << 10, i), start=t + 1.0)
    assert tier.under_pressure()
    cl.tick_flush()
    assert not tier.under_pressure()
    assert cl.flusher.counters.get("tier_demotions", 0) > 0
    assert "tier.tiered" in cl.dirty_counts()
    cl.close()


def test_unknown_backend_binding_rejected(workdir):
    with pytest.raises(AssertionError):
        make_cluster(workdir, n=1,
                     buckets=[BucketMount("b", "b", backend="nope")])


# ---------------------------------------------------------------------------
# metamorphic: a single-backend binding reproduces the default store exactly
# ---------------------------------------------------------------------------

def _workload(cl):
    fs = make_fs(cl)
    fs.makedirs("/b/d")
    for i in range(6):
        sz = (64 << 10) if i % 2 else (CHUNK * 3)   # put + MPU paths
        fs.write_file(f"/b/d/f{i}.bin", _blob(sz, i))
    cl.drain_dirty(max_rounds=16)
    for i in range(6):
        fs.read_file(f"/b/d/f{i}.bin")
    fs.listdir("/b/d")
    return fs


def test_single_backend_binding_is_fingerprint_identical(tmp_path):
    """Binding the bucket to an explicitly-registered CosStore (instead of
    the implicit default) must reproduce byte-identical filesystem state
    AND identical virtual end times — the tiering seam adds nothing when
    there is no tier stack."""
    cl_a = make_cluster(str(tmp_path / "a"), n=3)
    fp_a = fs_fingerprint(_workload(cl_a))
    t_a = cl_a.clock.now
    cos_a = cl_a.cos.ops.copy()
    cl_a.close()

    clock_b = SimClock()
    explicit = CosStore(clock_b, HardwareModel())
    cl_b = make_cluster(str(tmp_path / "b"), n=3,
                        buckets=[BucketMount("b", "b", backend="s3b")],
                        backends={"s3b": explicit}, clock=clock_b)
    fp_b = fs_fingerprint(_workload(cl_b))
    t_b = cl_b.clock.now
    cl_b.close()

    assert fp_a == fp_b
    assert t_a == pytest.approx(t_b, abs=0.0), \
        "explicit single-backend binding must not change virtual time"
    assert cos_a == explicit.ops, "same COS op mix through either binding"
