#!/usr/bin/env python
"""Fail on broken intra-repo references in the repo's markdown docs.

Two kinds of reference are checked:

* markdown links ``[text](target)`` whose target is not an external URL or
  a pure ``#anchor`` — the target (anchor stripped) must exist exactly
  where a renderer would look: relative to the *referencing file* (or the
  repo root for ``/``-prefixed targets).  No other fallback roots — a
  link that 404s on GitHub must fail here;
* backtick spans that look like repo file paths (``core/loadgen.py``,
  ``scripts/check.sh``, ``reports/bench/traffic.json``) — resolved against
  the repo root, ``src/repro`` (module docs drop the package prefix),
  ``src``, and the referencing file's directory; a bare filename
  (``state.py``) passes if any file in the repo has that basename.

Spans containing glob characters are skipped, as are PAPER.md / PAPERS.md /
SNIPPETS.md (quoted external material), CHANGES.md (append-only history),
and ISSUE.md (per-PR scratch).  Run via ``scripts/check.sh --docs`` or
directly: ``python scripts/check_docs.py``.
"""

from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_FILES = {"PAPER.md", "PAPERS.md", "SNIPPETS.md", "CHANGES.md",
              "ISSUE.md"}
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", "node_modules"}

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_SPAN = re.compile(r"`([A-Za-z0-9_.\-/]+\.(?:py|sh|md|json|toml|txt))`")


def repo_markdown_files() -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(REPO):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for fn in filenames:
            if fn.endswith(".md") and fn not in SKIP_FILES:
                out.append(os.path.join(dirpath, fn))
    return sorted(out)


def basename_index() -> set[str]:
    names: set[str] = set()
    for dirpath, dirnames, filenames in os.walk(REPO):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        names.update(filenames)
    return names


def resolve(target: str, md_dir: str) -> bool:
    """Multi-root resolution for prose path *spans*, which drop package
    prefixes by convention (``core/flusher.py`` ≙ src/repro/core/…)."""
    roots = [REPO, os.path.join(REPO, "src", "repro"),
             os.path.join(REPO, "src"), md_dir]
    return any(os.path.exists(os.path.join(r, target)) for r in roots)


def resolve_link(target: str, md_dir: str) -> bool:
    """Markdown links resolve the way a renderer resolves them: relative
    to the referencing file, or to the repo root when ``/``-prefixed.
    Span-style fallback roots would let links that 404 on GitHub pass."""
    if target.startswith("/"):
        return os.path.exists(os.path.join(REPO, target.lstrip("/")))
    return os.path.exists(os.path.normpath(os.path.join(md_dir, target)))


def check_file(path: str, basenames: set[str]) -> list[str]:
    md_dir = os.path.dirname(path)
    rel = os.path.relpath(path, REPO)
    errs: list[str] = []
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for m in MD_LINK.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:",
                                      "#")):
                    continue
                target = target.split("#", 1)[0]
                if target and not resolve_link(target, md_dir):
                    errs.append(f"{rel}:{lineno}: broken link ({target})")
            for m in PATH_SPAN.finditer(line):
                span = m.group(1)
                if "*" in span or "<" in span:
                    continue
                if "/" in span:
                    if not resolve(span, md_dir):
                        errs.append(f"{rel}:{lineno}: missing path "
                                    f"(`{span}`)")
                elif span not in basenames:
                    errs.append(f"{rel}:{lineno}: no file named `{span}` "
                                f"in the repo")
    return errs


def main() -> int:
    basenames = basename_index()
    files = repo_markdown_files()
    errs: list[str] = []
    for path in files:
        errs.extend(check_file(path, basenames))
    if errs:
        print("\n".join(errs), file=sys.stderr)
        print(f"[docs] {len(errs)} broken reference(s) across "
              f"{len(files)} markdown files", file=sys.stderr)
        return 1
    print(f"[docs] OK: {len(files)} markdown files, all intra-repo "
          f"references resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
