#!/usr/bin/env bash
# One-shot pre-commit gate: byte-compile everything, then run the tier-1
# test suite (pyproject's addopts already excludes `slow` JAX smoke tests;
# run those with `pytest -m slow` when touching kernels/models).
#
#   scripts/check.sh            full gate (compile, tests, smokes, docs)
#   scripts/check.sh --docs     docs link check only
set -euo pipefail
cd "$(dirname "$0")/.."

if [[ "${1:-}" == "--docs" ]]; then
    exec python scripts/check_docs.py
fi

echo "== compileall =="
python -m compileall -q src benchmarks tests

echo "== tier-1 pytest =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

echo "== flush-bench smoke =="
# drains 256 dirty files through the background flusher and fails on a
# >20% virtual-time regression vs reports/bench/flush_smoke_baseline.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.flush_smoke --check

echo "== rpc-count smoke =="
# fixed metadata+data workload; fails if RPC envelopes or typed sub-calls
# grow >20% vs reports/bench/rpc_smoke_baseline.json (metadata fast paths)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.rpc_smoke --check

echo "== traffic-qos smoke =="
# open-loop low-load + 2x-overload points; fails if tail latency, gold shed
# rate, or best-effort shed rate regress vs traffic_smoke_baseline.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.traffic_smoke --check

echo "== kv-cache smoke =="
# KV-block put/drain + tiered gets on a scale-to-zero survivor; fails on a
# >20% virtual-time or RPC-envelope regression vs kv_smoke_baseline.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.kv_smoke --check

echo "== tier-storage smoke =="
# cold/warm/hot sweep over a tiered (NVMe-over-COS) mount plus a write-back
# durability pass; fails on a >20% virtual-time regression vs
# tier_smoke_baseline.json or if any tier-dirty byte survives zero-scale
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.tier_smoke --check

echo "== docs links =="
# broken intra-repo references (markdown links + backticked repo paths)
python scripts/check_docs.py
