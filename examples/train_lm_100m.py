"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
objcache-backed data + checkpoints, kill the run midway, and resume from
the latest durable checkpoint.

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 200]

(~100M params: a 12L/512d/8H dense decoder — CPU-trainable; the full-scale
production configs are exercised by the dry-run instead.)
"""

import argparse
import shutil
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (BucketMount, ClientConfig, Cluster, ObjcacheClient,
                        ObjcacheFS, ServerConfig)
from repro.data import TokenPipeline, synth_corpus_to_cos
from repro.models import build_model
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig
from repro.train import make_train_step, train_state_init

CFG_100M = ArchConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=1536, vocab=32768, rope_theta=1e4,
    tie_embeddings=True)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-every", type=int, default=50)
args = ap.parse_args()

workdir = tempfile.mkdtemp(prefix="objcache-train100m-")
try:
    cluster = Cluster(workdir, [BucketMount("train", "train")],
                      cfg=ServerConfig(chunk_size=1 << 20))
    cluster.start(2)
    fs = ObjcacheFS(ObjcacheClient(cluster.router, cluster.clock, "n0",
                                   ClientConfig(consistency="weak"),
                                   chunk_size=1 << 20))
    synth_corpus_to_cos(cluster.cos, "train", "corpus", n_shards=4,
                        tokens_per_shard=args.batch * (args.seq + 1) * 16,
                        vocab=CFG_100M.vocab)
    pipe = TokenPipeline(fs, "/train/corpus", batch=args.batch,
                        seq_len=args.seq)
    ckpt = CheckpointManager(fs, "/train/ckpt")

    model = build_model(CFG_100M)
    state, _ = train_state_init(model, jax.random.PRNGKey(0),
                                max_seq=args.seq)
    n = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {n / 1e6:.1f}M params")
    step_fn = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-4, warmup_steps=20,
                           total_steps=args.steps)))

    def train_until(state, start, stop, epoch=0):
        it = iter(pipe.batches(epoch=epoch))
        t0 = time.time()
        losses = []
        for step in range(start, stop):
            try:
                batch = next(it)
            except StopIteration:
                epoch += 1
                it = iter(pipe.batches(epoch=epoch))
                batch = next(it)
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % 25 == 0:
                print(f"  step {step + 1:4d} loss {losses[-1]:7.4f} "
                      f"({time.time() - t0:5.1f}s)")
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state, durable=True)
                print(f"  checkpoint @ {step + 1} (durable)")
        return state, losses

    half = args.steps // 2
    print(f"phase 1: steps 0..{half}")
    state, losses1 = train_until(state, 0, half)

    # simulate a node failure taking the run down, then resume
    print("simulating crash: all cache nodes restart, trainer restarts")
    for nm in list(cluster.node_list()):
        cluster.crash_node(nm)
        cluster.restart_node(nm)
    latest = ckpt.latest_step()
    fresh, _ = train_state_init(model, jax.random.PRNGKey(0),
                                max_seq=args.seq)
    state = ckpt.restore(latest, like=fresh)
    print(f"resumed from step {latest}")

    print(f"phase 2: steps {latest}..{args.steps}")
    state, losses2 = train_until(state, latest, args.steps)
    print(f"final loss {losses2[-1]:.4f} (start {losses1[0]:.4f}) — "
          f"{'improved' if losses2[-1] < losses1[0] else 'no improvement'}")
    cluster.drain_dirty()
finally:
    shutil.rmtree(workdir, ignore_errors=True)
print("train_lm_100m OK")
