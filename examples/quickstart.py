"""Quickstart: mount a bucket, read/write through the cache, persist, scale.

    PYTHONPATH=src python examples/quickstart.py
"""

import shutil
import sys
import tempfile

sys.path.insert(0, "src")

from repro.core import (BucketMount, ClientConfig, Cluster, ObjcacheClient,
                        ObjcacheFS, ServerConfig)

workdir = tempfile.mkdtemp(prefix="objcache-quickstart-")
try:
    # external storage with some pre-existing objects
    cluster = Cluster(workdir, [BucketMount("data", "data")],
                      cfg=ServerConfig(chunk_size=1 << 20))
    cluster.cos.put_object("data", "inputs/a.txt", b"hello external storage")
    cluster.start(3)                      # three cache servers

    # a node-local client (the FUSE-process role) and the POSIX-ish surface
    client = ObjcacheClient(cluster.router, cluster.clock, "n0",
                            ClientConfig(consistency="strict"),
                            chunk_size=1 << 20)
    fs = ObjcacheFS(client)

    print("listing /data:", fs.listdir("/data"))
    print("read-through:", fs.read_file("/data/inputs/a.txt"))

    # write-back: visible cluster-wide immediately, durable on fsync
    fs.makedirs("/data/outputs")
    fs.write_file("/data/outputs/result.bin", b"\x01" * (3 << 20))
    fh = fs.open("/data/outputs/result.bin", "r+")
    fs.fsync(fh)                          # Fig. 8 persisting transaction
    fs.close(fh)
    print("in COS after fsync:",
          cluster.cos.exists("data", "outputs/result.bin"))

    # elasticity: grow, then scale to zero — dirty state lands in COS
    st = cluster.add_node()
    print(f"joined {st.node} in {st.duration * 1000:.1f} virtual-ms "
          f"(migrated {st.migrated_chunks} dirty chunks)")
    fs.write_file("/data/outputs/late.bin", b"\x02" * (1 << 20))
    for nm in list(cluster.node_list()):
        cluster.remove_node(nm)
    print("zero-scaled; late.bin in COS:",
          cluster.cos.exists("data", "outputs/late.bin"))
finally:
    shutil.rmtree(workdir, ignore_errors=True)
print("quickstart OK")
