"""Serving scenario (paper §6.3): publish a model to COS, then start serving
replicas that load through the three cache tiers — cold COS miss, warm
cluster, warm node — and serve batched greedy generation.

    PYTHONPATH=src python examples/serve_with_cache_tiers.py
"""

import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_reduced
from repro.core import (BucketMount, ClientConfig, Cluster, ObjcacheClient,
                        ObjcacheFS, ServerConfig)
from repro.models import build_model
from repro.serving import ModelStore, ServingEngine
from repro.train import train_state_init

workdir = tempfile.mkdtemp(prefix="objcache-serve-")
try:
    cluster = Cluster(workdir, [BucketMount("models", "models")],
                      cfg=ServerConfig(chunk_size=1 << 20))
    cluster.start(3)

    def fs_on(node):
        return ObjcacheFS(ObjcacheClient(
            cluster.router, cluster.clock, node,
            ClientConfig(consistency="weak"), chunk_size=1 << 20))

    cfg = get_reduced("qwen3-0.6b")
    model = build_model(cfg)
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=64)

    # publish: a trainer saves the model durably (lands in COS)
    pub = fs_on("n0")
    CheckpointManager(pub, "/models/qwen3-tiny").save(0, state.params,
                                                      durable=True)
    # wipe the cluster (fresh serving fleet, cold caches)
    for nm in list(cluster.node_list()):
        cluster.remove_node(nm)
    cluster2 = Cluster(workdir + "-serve",
                       [BucketMount("models", "models")],
                       cfg=ServerConfig(chunk_size=1 << 20),
                       cos=cluster.cos)
    cluster2.start(3)

    def load_on(node):
        fs = fs_on_2(node)
        store = ModelStore(fs, "/models/qwen3-tiny")
        t0 = cluster2.clock.now
        params, nbytes = store.load(0, like=state.params)
        return params, nbytes, cluster2.clock.now - t0

    def fs_on_2(node):
        return ObjcacheFS(ObjcacheClient(
            cluster2.router, cluster2.clock, node,
            ClientConfig(consistency="weak"), chunk_size=1 << 20))

    params, nbytes, t_cold = load_on("n0")      # replica 1: COS miss
    _, _, t_cluster = load_on("n1")             # replica 2: cluster tier
    _, _, t_node = load_on("n1")                # replica 2 restart: node tier
    print(f"model {nbytes / 1e6:.1f} MB | cold {t_cold:.3f}s | "
          f"cluster {t_cluster:.3f}s | node {t_node:.3f}s (virtual)")

    engine = ServingEngine(build_model(cfg), params, max_len=64)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=6, dtype=np.int32)
               for _ in range(4)]
    outs = engine.generate(prompts, max_new=6)
    for i, o in enumerate(outs):
        print(f"  request {i}: generated {o}")
    assert t_node <= t_cluster <= t_cold
finally:
    shutil.rmtree(workdir, ignore_errors=True)
    shutil.rmtree(workdir + "-serve", ignore_errors=True)
print("serve_with_cache_tiers OK")
