"""Elasticity scenario (paper §6.5): a training job keeps checkpointing
while the cache cluster scales 1 → 6 nodes and back down to zero; every
checkpoint survives in external storage.

    PYTHONPATH=src python examples/elastic_scaling.py
"""

import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import numpy as np

from repro.core import (BucketMount, ClientConfig, Cluster, ObjcacheClient,
                        ObjcacheFS, ServerConfig)

workdir = tempfile.mkdtemp(prefix="objcache-elastic-")
try:
    cluster = Cluster(workdir, [BucketMount("ckpt", "ckpt")],
                      cfg=ServerConfig(chunk_size=256 << 10))
    cluster.start(1)
    fs = ObjcacheFS(ObjcacheClient(cluster.router, cluster.clock, "n0",
                                   ClientConfig(consistency="weak"),
                                   chunk_size=256 << 10))
    rng = np.random.default_rng(0)
    written = {}

    def checkpoint(step):
        data = rng.bytes(int(rng.integers(256, 1024)) << 10)
        path = f"/ckpt/run/step_{step}.bin"
        fs.makedirs("/ckpt/run")
        fs.write_file(path, data)
        written[f"run/step_{step}.bin"] = data

    step = 0
    print("scaling up 1 -> 6 while checkpointing:")
    for _ in range(5):
        checkpoint(step := step + 1)
        st = cluster.add_node()
        fs.client._pull_node_list()
        print(f"  +{st.node}: {st.duration * 1000:7.1f} virtual-ms, "
              f"migrated {st.migrated_chunks} chunks / "
              f"{st.migrated_dirs} dirs "
              f"({st.migrated_bytes >> 10} KiB)")

    print("scaling down 6 -> 0 (dirty data is uploaded, not lost):")
    for nm in list(cluster.node_list()):
        checkpoint(step := step + 1)
        st = cluster.remove_node(nm)
        if cluster.servers:
            fs.client._pull_node_list()
        print(f"  -{nm}: {st.duration * 1000:7.1f} virtual-ms, "
              f"uploaded {st.uploaded_inodes} inodes")

    missing = [k for k, v in written.items()
               if not cluster.cos.exists("ckpt", k)
               or cluster.cos.get_object("ckpt", k)[0] != v]
    assert not missing, missing
    print(f"all {len(written)} checkpoints intact in external storage")
finally:
    shutil.rmtree(workdir, ignore_errors=True)
print("elastic_scaling OK")
