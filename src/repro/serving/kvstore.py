"""KV-cache persistence over ObjcacheFS — inference state as a workload.

The paper's serving story (§6.3, Fig. 11) moves *parameters* through the
cache tiers; this module moves *inference state*.  ObjectCache (PAPERS.md,
arXiv 2605.22850) shows that layerwise LLM KV caches retrieved from object
storage are exactly the access shape an elastic filesystem cache
accelerates: immutable-once-written blocks, keyed by prompt prefix, read
back layer-at-a-time at the start of a request.  Writing them through
`ObjcacheFS` means they inherit everything the data path already has —
dirty tracking, background write-back (`core/flusher.py`), eviction to COS
under cache pressure, migration on ring changes, and durable survival of a
scale-to-zero drain — with no new protocol code.

Layout per stored prefix (all paths under the store root, typically a
mounted bucket directory)::

    <root>/<key>/blocks.bin       # per-layer segments, concatenated
    <root>/<key>/manifest.json    # tokens, cache_len, segment table

``key`` is a hash of the exact token prefix.  ``blocks.bin`` holds one
segment per (cache leaf, layer period) — the layerwise block granularity
ObjectCache fetches — and the manifest records each segment's
(offset, nbytes, dtype, shape), so a reader can fetch any single layer
with one ranged read (`ObjcacheFS.read_file_range`).  The manifest is
committed with the same write-then-rename discipline as
`checkpoint/manager.py`: rename is a 2PC transaction, so a prefix either
has a complete manifest or is invisible — a crashed writer never publishes
a partial cache.

Snapshot/lookup contract (why prefixes are stored at *block* lengths):
SSM state (`models/mamba2.py`) is a cumulative recurrence — unlike an
attention KV cache it cannot be truncated to a shorter prefix after the
fact.  The store therefore saves snapshots only at agreed lengths
(`snapshot_lens`: every ``block_tokens``-th position plus ``prompt_len-1``,
the state that emits the first token), and `lookup` probes exactly those
lengths, longest first.  Restoring a snapshot is bit-exact: segments are
raw array bytes, and a zero-padded tail along the kv axis is invisible to
`attention_decode`'s ``cache_len`` mask (and to an unwrapped ring buffer).

This module is numpy-only on purpose: the benchmark gate
(`benchmarks/kv_smoke.py`) exercises the data path without importing JAX.
Caches are nested dicts whose leaves are arrays shaped
``(n_periods, batch, ...)`` — the layout of `models.lm.init_cache` — and
`put`/`get` move one batch row at a time.
"""

from __future__ import annotations

import hashlib
import json

import numpy as np

from ..core.fs import ObjcacheFS

_BLOCKS = "blocks.bin"
_MANIFEST = "manifest.json"


def prefix_key(tokens) -> str:
    """Content hash of a token prefix (dtype-pinned so python ints, int32
    and int64 arrays of the same tokens all map to the same key)."""
    raw = np.ascontiguousarray(np.asarray(tokens, np.int32)).tobytes()
    return hashlib.sha1(raw).hexdigest()[:20]


def _flat_items(tree, prefix: str = "") -> list[tuple[str, object]]:
    """Nested-dict flatten with '/'-joined paths, sorted for a stable
    segment order (no jax.tree dependency)."""
    out: list[tuple[str, object]] = []
    for k in sorted(tree):
        v = tree[k]
        path = f"{prefix}/{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.extend(_flat_items(v, path))
        else:
            out.append((path, v))
    return out


def _unflatten(items: dict[str, np.ndarray]) -> dict:
    out: dict = {}
    for path, arr in items.items():
        cur = out
        parts = path.split("/")
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = arr
    return out


def _fit(arr: np.ndarray, target_shape: tuple[int, ...], cache_len: int,
         leaf: str) -> np.ndarray:
    """Adapt a restored (n_periods, ...) leaf to `target_shape`.  At most
    one axis may differ (the kv/time axis, when the reader's ``max_len``
    differs from the writer's); resizing it is exact only while the first
    ``cache_len`` positions are the only live ones, i.e. the cache has not
    wrapped past the smaller of the two sizes."""
    if arr.shape == tuple(target_shape):
        return arr
    diff = [i for i, (a, b) in enumerate(zip(arr.shape, target_shape))
            if a != b]
    if len(arr.shape) != len(target_shape) or len(diff) != 1:
        raise ValueError(f"kv block {leaf!r}: stored shape {arr.shape} "
                         f"incompatible with target {tuple(target_shape)}")
    ax = diff[0]
    lo = min(arr.shape[ax], target_shape[ax])
    if cache_len > lo:
        raise ValueError(
            f"kv block {leaf!r}: cannot resize axis {ax} from "
            f"{arr.shape[ax]} to {target_shape[ax]} with cache_len="
            f"{cache_len} live positions (cache wrapped)")
    out = np.zeros(target_shape, arr.dtype)
    sel = [slice(None)] * arr.ndim
    sel[ax] = slice(0, lo)
    out[tuple(sel)] = arr[tuple(sel)]
    return out


class KVCacheStore:
    """Prefix-keyed KV/SSM-state block store over an `ObjcacheFS` mount.

    One store instance is one serving replica's view; different replicas
    (different FS clients, possibly different nodes) sharing a root see
    each other's prefixes through the cluster cache — that sharing is the
    cluster-cache tier of `benchmarks/kv_reuse.py`.
    """

    def __init__(self, fs: ObjcacheFS, root: str,
                 block_tokens: int = 16) -> None:
        assert block_tokens >= 1
        self.fs = fs
        self.root = root.rstrip("/")
        self.block_tokens = block_tokens
        # counters surfaced by benchmarks: puts/put_bytes on the write side,
        # hits/misses/probes on the lookup side, get_bytes on the read side
        self.stats: dict[str, int] = {
            "puts": 0, "put_bytes": 0, "dup_puts": 0,
            "hits": 0, "misses": 0, "probes": 0, "gets": 0, "get_bytes": 0,
        }

    # ------------------------------------------------------------------
    # snapshot/lookup length contract
    # ------------------------------------------------------------------
    def snapshot_lens(self, prompt_len: int) -> list[int]:
        """Prefix lengths worth persisting while prefilling a prompt of
        `prompt_len` tokens: every block boundary (shareable with any
        request whose prompt continues past it) plus ``prompt_len - 1``
        (the exact-hit state that emits this prompt's first token)."""
        lens = {k for k in range(self.block_tokens, prompt_len,
                                 self.block_tokens)}
        if prompt_len > 1:
            lens.add(prompt_len - 1)
        return sorted(lens)

    def candidate_lens(self, cap: int) -> list[int]:
        """Lengths `lookup` probes, longest first: `cap` itself plus every
        block boundary below it.  O(cap / block_tokens) existence probes
        bound the metadata cost of a miss."""
        out = {cap} if cap >= 1 else set()
        out.update(k for k in range(self.block_tokens, cap,
                                    self.block_tokens))
        return sorted(out, reverse=True)

    # ------------------------------------------------------------------
    # store / fetch
    # ------------------------------------------------------------------
    def _dir(self, key: str) -> str:
        return f"{self.root}/{key}"

    def has(self, tokens) -> bool:
        return self.fs.exists(f"{self._dir(prefix_key(tokens))}/{_MANIFEST}")

    def put(self, tokens, cache: dict, batch_index: int = 0) -> dict | None:
        """Persist one batch row of `cache` keyed by the exact `tokens`
        prefix.  Returns the manifest, or None if this prefix is already
        stored (first writer wins; blocks are immutable once published)."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            return None
        key = prefix_key(tokens)
        d = self._dir(key)
        if self.fs.exists(f"{d}/{_MANIFEST}"):
            self.stats["dup_puts"] += 1
            return None
        segs: list[dict] = []
        blobs: list[bytes] = []
        off = 0
        for leaf, arr in _flat_items(cache):
            arr = np.asarray(arr)
            if arr.ndim < 2 or batch_index >= arr.shape[1]:
                raise ValueError(f"cache leaf {leaf!r}: expected "
                                 f"(n_periods, batch, ...), got {arr.shape}")
            row = np.ascontiguousarray(arr[:, batch_index])
            for p in range(row.shape[0]):          # one block per layer period
                raw = np.ascontiguousarray(row[p]).tobytes()
                segs.append({"leaf": leaf, "period": p, "offset": off,
                             "nbytes": len(raw), "dtype": str(arr.dtype),
                             "shape": list(row.shape[1:])})
                blobs.append(raw)
                off += len(raw)
        self.fs.makedirs(d)
        self.fs.write_file(f"{d}/{_BLOCKS}", b"".join(blobs))
        manifest = {"key": key, "tokens": tokens.tolist(),
                    "cache_len": int(tokens.size), "nbytes": off,
                    "n_blocks": len(segs), "layers": segs}
        tmp = f"{d}/.manifest.tmp"
        self.fs.write_file(tmp, json.dumps(manifest).encode())
        self.fs.rename(tmp, f"{d}/{_MANIFEST}")   # 2PC publish point
        self.stats["puts"] += 1
        self.stats["put_bytes"] += off
        return manifest

    def lookup(self, tokens, cap: int | None = None
               ) -> tuple[int, str] | None:
        """Longest stored prefix of `tokens`, probing only the snapshot
        lengths.  `cap` bounds the usable length — a serving engine passes
        ``len(prompt) - 1`` because the final prompt token must always be
        fed through decode to produce first-token logits.  Returns
        ``(length, key)`` or None."""
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        cap = tokens.size if cap is None else min(cap, tokens.size)
        for ln in self.candidate_lens(cap):
            self.stats["probes"] += 1
            key = prefix_key(tokens[:ln])
            if self.fs.exists(f"{self._dir(key)}/{_MANIFEST}"):
                self.stats["hits"] += 1
                return ln, key
        self.stats["misses"] += 1
        return None

    def manifest(self, key: str) -> dict:
        return json.loads(self.fs.read_file(f"{self._dir(key)}/{_MANIFEST}"))

    def get(self, key: str, like: dict | None = None,
            layers: set[str] | None = None) -> tuple[dict, dict]:
        """Fetch stored blocks for `key` as a batch-1 cache (nested dict of
        numpy arrays shaped ``(n_periods, 1, ...)``).

        `like` (a cache pytree of the same structure, e.g. the engine's
        freshly initialized cache) pins target shapes/dtypes: kv axes are
        zero-padded or sliced to the reader's ``max_len`` where that is
        exact (see `_fit`), and a dtype mismatch is an error, not a cast —
        a silently converted cache would break bit-determinism.

        `layers` optionally restricts the fetch to a subset of leaf paths
        (e.g. ``{"slot0/k"}``); each selected segment is one ranged read,
        the layerwise-retrieval pattern of ObjectCache.  Returns
        ``(cache, manifest)``."""
        man = self.manifest(key)
        path = f"{self._dir(key)}/{_BLOCKS}"
        per_leaf: dict[str, dict[int, np.ndarray]] = {}
        want = [s for s in man["layers"]
                if layers is None or s["leaf"] in layers]
        if layers is None:
            # whole-prefix restore: one sequential read of the blocks file
            raw_all = self.fs.read_file(path)
            raws = [raw_all[s["offset"]:s["offset"] + s["nbytes"]]
                    for s in want]
        else:
            raws = [self.fs.read_file_range(path, s["offset"], s["nbytes"])
                    for s in want]
        for seg, raw in zip(want, raws):
            if len(raw) != seg["nbytes"]:
                raise ValueError(
                    f"kv block {seg['leaf']}[{seg['period']}] of {key}: "
                    f"read {len(raw)} bytes, manifest says {seg['nbytes']}")
            arr = np.frombuffer(raw, dtype=seg["dtype"]).reshape(seg["shape"])
            per_leaf.setdefault(seg["leaf"], {})[seg["period"]] = arr
            self.stats["get_bytes"] += len(raw)
        self.stats["gets"] += 1
        like_flat = dict(_flat_items(like)) if like is not None else {}
        leaves: dict[str, np.ndarray] = {}
        for leaf, periods in per_leaf.items():
            stacked = np.stack([periods[p] for p in sorted(periods)])
            tgt = like_flat.get(leaf)
            if tgt is not None:
                tgt = np.asarray(tgt)
                if str(tgt.dtype) != str(stacked.dtype):
                    raise ValueError(
                        f"kv block {leaf!r}: stored dtype {stacked.dtype} "
                        f"!= cache dtype {tgt.dtype}")
                # target per-row shape: drop the batch axis
                row_shape = (tgt.shape[0],) + tuple(tgt.shape[2:])
                stacked = _fit(stacked, row_shape, man["cache_len"], leaf)
            leaves[leaf] = stacked[:, None]        # re-insert batch axis
        return _unflatten(leaves), man
