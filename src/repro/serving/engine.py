"""Model serving over objcache — the paper's §6.3 use case (Triton startup).

`ModelStore.load()` pulls every model file through the mounted FS: a cold
start pays the COS fetch, a warm cluster pays the cluster-local tier, a
restarted replica on the same node pays only the node-local tier — the
three bars of Fig. 11.  `ServingEngine` then runs batched prefill+decode
with the JAX model (real compute; reduced configs in examples/tests).

With a `KVCacheStore` attached, the engine also persists *inference state*
(per-layer attention KV and SSM-state blocks) through the same cache
tiers: `generate_with_reuse` looks up the longest stored prefix of the
prompt, restores that snapshot (partial-prefill resume — decode continues
from the restored ``cache_len``), and saves new snapshots at block
boundaries while prefilling.  A replica warm-restarting after a
scale-to-zero drain reloads params *and* hot KV blocks from COS/cluster
tiers; `benchmarks/kv_reuse.py` measures the resulting time-to-first-token
across the tiers with the Fig. 11 methodology.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.fs import ObjcacheFS
from ..models import Model


class ModelStore:
    """Loads checkpointed params through any FS exposing read_file/listdir
    (ObjcacheFS, S3FSLike adapter, or S3Direct adapter)."""

    def __init__(self, fs, root: str) -> None:
        self.fs = fs
        self.root = root.rstrip("/")

    def load(self, step: int, like) -> tuple[object, int]:
        """Returns (params, bytes_read).  Every leaf file goes through the
        cache tiers.  Raises `ValueError` on a manifest that does not match
        its leaf files (truncated/mismatched dtype bytes) or does not cover
        the `like` tree — a partially published checkpoint must fail loudly,
        not deserialize garbage."""
        d = f"{self.root}/step_{step}"
        manifest = json.loads(self.fs.read_file(f"{d}/manifest.json"))
        flat = {}
        nbytes = 0
        for key, info in manifest["leaves"].items():
            raw = self.fs.read_file(f"{d}/{key}.bin")
            nbytes += len(raw)
            want = int(np.prod(info["shape"], dtype=np.int64)) * \
                np.dtype(info["dtype"]).itemsize
            if len(raw) != want:
                raise ValueError(
                    f"checkpoint leaf {key!r} at {d}: {len(raw)} bytes on "
                    f"disk, manifest says {info['dtype']}{info['shape']} "
                    f"= {want} bytes")
            flat[key] = np.frombuffer(raw, dtype=info["dtype"]).reshape(
                info["shape"])
        leaves = jax.tree_util.tree_flatten_with_path(like)[0]
        from ..checkpoint.manager import _key_str
        missing = []
        rebuilt = []
        for path, leaf in leaves:
            key = ".".join(_key_str(k) for k in path)
            if key not in flat:
                missing.append(key)
                continue
            rebuilt.append(jnp.asarray(flat[key], dtype=leaf.dtype))
        if missing:
            raise ValueError(f"checkpoint manifest at {d} is missing "
                             f"leaves: {', '.join(missing)}")
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, rebuilt), nbytes


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: list = field(default_factory=list)


class ServingEngine:
    """Minimal batched serving loop: collect requests, left-align into a
    batch, prefill, then decode greedily in lockstep.

    With a `kvstore` (a `serving.kvstore.KVCacheStore`), single-request
    generation can resume from persisted prefix state: see
    `generate_with_reuse`."""

    def __init__(self, model: Model, params, max_len: int = 256,
                 kvstore=None) -> None:
        self.model = model
        self.params = params
        self.max_len = max_len
        self.kvstore = kvstore
        self._decode = jax.jit(model.decode)
        self._prefill_tok = jax.jit(
            lambda p, b: model.prefill(p, b))

    def generate(self, prompts: list[np.ndarray], max_new: int = 8
                 ) -> list[list[int]]:
        assert prompts, "no requests"
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p      # left-pad
        cache = self.model.init_cache(b, self.max_len)

        # prefill token-by-token through the decode path (keeps the cache
        # exact for every arch family, incl. ring buffers and SSM state)
        cache_len = jnp.int32(0)
        logits = None
        for t in range(plen):
            logits, cache = self._decode(self.params,
                                         jnp.asarray(toks[:, t:t + 1]),
                                         cache, cache_len)
            cache_len = cache_len + 1
        outs: list[list[int]] = [[] for _ in range(b)]
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i in range(b):
            outs[i].append(int(tok[i, 0]))
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, tok, cache, cache_len)
            cache_len = cache_len + 1
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for i in range(b):
                outs[i].append(int(tok[i, 0]))
        return outs

    def generate_with_reuse(self, prompt: np.ndarray, max_new: int = 8,
                            store: bool = True) -> tuple[list[int], dict]:
        """Single-request generation with KV-prefix reuse.

        Looks up the longest persisted prefix of `prompt` (capped at
        ``len(prompt) - 1``: the final prompt token always runs through
        decode so first-token logits exist), restores that snapshot into a
        fresh cache, and prefills only the remaining tokens — partial-
        prefill resume.  While prefilling, snapshots are written back at
        the store's block boundaries (and at ``len(prompt) - 1``) so later
        requests sharing the prefix start further along.  Decoding is
        identical to `generate` from there, so the emitted tokens are
        bit-identical with and without reuse (tier-1 asserts this).

        Returns ``(tokens, info)``; `info` reports ``reused_len``,
        ``prefill_steps`` (tokens actually pushed through decode),
        ``exact_hit`` (only the final prompt token ran), and
        ``kv_read_bytes`` — the benchmark's TTFT inputs."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        s = prompt.size
        assert 1 <= s <= self.max_len, (s, self.max_len)
        cache = self.model.init_cache(1, self.max_len)
        start = 0
        info = {"reused_len": 0, "prefill_steps": 0, "exact_hit": False,
                "kv_read_bytes": 0, "kv_stored": 0}
        kv = self.kvstore
        if kv is not None:
            hit = kv.lookup(prompt, cap=s - 1)
            if hit is not None:
                start, key = hit
                restored, man = kv.get(key, like=cache)
                cache = jax.tree.map(
                    lambda like_leaf, a: jnp.asarray(a, like_leaf.dtype),
                    cache, restored)
                info.update(reused_len=start, exact_hit=(start == s - 1),
                            kv_read_bytes=man["nbytes"])
        cache_len = jnp.int32(start)
        toks = prompt[None, :]
        logits = None
        snap_lens = set(kv.snapshot_lens(s)) if (kv is not None and store) \
            else ()
        for t in range(start, s):
            logits, cache = self._decode(self.params,
                                         jnp.asarray(toks[:, t:t + 1]),
                                         cache, cache_len)
            cache_len = cache_len + 1
            info["prefill_steps"] += 1
            if (t + 1) in snap_lens and (t + 1) > start:
                if kv.put(prompt[:t + 1], cache) is not None:
                    info["kv_stored"] += 1
        out: list[int] = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, tok, cache, cache_len)
            cache_len = cache_len + 1
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            out.append(int(tok[0, 0]))
        return out, info
