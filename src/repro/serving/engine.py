"""Model serving over objcache — the paper's §6.3 use case (Triton startup).

`ModelStore.load()` pulls every model file through the mounted FS: a cold
start pays the COS fetch, a warm cluster pays the cluster-local tier, a
restarted replica on the same node pays only the node-local tier — the
three bars of Fig. 11.  `ServingEngine` then runs batched prefill+decode
with the JAX model (real compute; reduced configs in examples/tests).
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..core.fs import ObjcacheFS
from ..models import Model


class ModelStore:
    """Loads checkpointed params through any FS exposing read_file/listdir
    (ObjcacheFS, S3FSLike adapter, or S3Direct adapter)."""

    def __init__(self, fs, root: str) -> None:
        self.fs = fs
        self.root = root.rstrip("/")

    def load(self, step: int, like) -> tuple[object, int]:
        """Returns (params, bytes_read).  Every leaf file goes through the
        cache tiers."""
        d = f"{self.root}/step_{step}"
        manifest = json.loads(self.fs.read_file(f"{d}/manifest.json"))
        flat = {}
        nbytes = 0
        for key, info in manifest["leaves"].items():
            raw = self.fs.read_file(f"{d}/{key}.bin")
            nbytes += len(raw)
            flat[key] = np.frombuffer(raw, dtype=info["dtype"]).reshape(
                info["shape"])
        leaves = jax.tree_util.tree_flatten_with_path(like)[0]
        from ..checkpoint.manager import _key_str
        rebuilt = []
        for path, leaf in leaves:
            key = ".".join(_key_str(k) for k in path)
            rebuilt.append(jnp.asarray(flat[key], dtype=leaf.dtype))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, rebuilt), nbytes


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int
    out: list = field(default_factory=list)


class ServingEngine:
    """Minimal batched serving loop: collect requests, left-align into a
    batch, prefill, then decode greedily in lockstep."""

    def __init__(self, model: Model, params, max_len: int = 256) -> None:
        self.model = model
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(model.decode)
        self._prefill_tok = jax.jit(
            lambda p, b: model.prefill(p, b))

    def generate(self, prompts: list[np.ndarray], max_new: int = 8
                 ) -> list[list[int]]:
        assert prompts, "no requests"
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p      # left-pad
        cache = self.model.init_cache(b, self.max_len)

        # prefill token-by-token through the decode path (keeps the cache
        # exact for every arch family, incl. ring buffers and SSM state)
        cache_len = jnp.int32(0)
        logits = None
        for t in range(plen):
            logits, cache = self._decode(self.params,
                                         jnp.asarray(toks[:, t:t + 1]),
                                         cache, cache_len)
            cache_len = cache_len + 1
        outs: list[list[int]] = [[] for _ in range(b)]
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for i in range(b):
            outs[i].append(int(tok[i, 0]))
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, tok, cache, cache_len)
            cache_len = cache_len + 1
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for i in range(b):
                outs[i].append(int(tok[i, 0]))
        return outs
