from .engine import ModelStore, ServingEngine

__all__ = ["ModelStore", "ServingEngine"]
