"""Serving over objcache: param loading (`ModelStore`), the batched
engine (`ServingEngine`), and KV-cache persistence (`KVCacheStore`).

`engine` imports JAX; it is loaded lazily so the numpy-only
`KVCacheStore` data path (used by `benchmarks/kv_smoke.py` in the
pre-commit gate) stays importable without paying the JAX startup cost."""

from .kvstore import KVCacheStore, prefix_key

__all__ = ["KVCacheStore", "ModelStore", "ServingEngine", "prefix_key"]


def __getattr__(name: str):
    if name in ("ModelStore", "ServingEngine"):
        from . import engine
        return getattr(engine, name)
    raise AttributeError(name)
