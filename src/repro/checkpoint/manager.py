"""Transactional checkpointing on ObjcacheFS (the paper's §6.4 use case).

Layout per step under the mounted bucket::

    <root>/step_<n>/manifest.json        # tree structure, shapes, dtypes
    <root>/step_<n>/<flat.leaf.path>.bin # raw little-endian array bytes

Commit discipline: leaves are written first, then the manifest is written
to a temporary name and renamed into place — objcache's rename is a 2PC
transaction, so a checkpoint either has a complete manifest or is invisible.
Durability to COS is *write-back*: `save()` returns after the cluster-local
commit; uploads overlap subsequent compute via the background flush
(`Cluster.tick_flush`), which is exactly the asynchronous-checkpoint
advantage Fig. 12 measures against S3FS's synchronous upload-on-close.
`save(..., durable=True)` additionally fsyncs every file (Fig. 8 persisting
transactions) before returning.
"""

from __future__ import annotations

import json

import jax
import numpy as np

from ..core.fs import ObjcacheFS


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = ".".join(_key_str(k) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


class CheckpointManager:
    def __init__(self, fs: ObjcacheFS, root: str) -> None:
        self.fs = fs
        self.root = root.rstrip("/")

    # ---- save ------------------------------------------------------------
    def save(self, step: int, tree, durable: bool = False) -> dict:
        d = f"{self.root}/step_{step}"
        self.fs.makedirs(d)
        flat = _flatten(tree)
        manifest = {"step": step, "leaves": {}}
        for key, arr in flat.items():
            path = f"{d}/{key}.bin"
            self.fs.write_file(path, arr.tobytes())
            manifest["leaves"][key] = {"shape": list(arr.shape),
                                       "dtype": str(arr.dtype)}
            if durable:
                fh = self.fs.open(path, "r+")
                self.fs.fsync(fh)
                self.fs.close(fh)
        tmp = f"{d}/.manifest.tmp"
        self.fs.write_file(tmp, json.dumps(manifest).encode())
        self.fs.rename(tmp, f"{d}/manifest.json")   # 2PC commit point
        if durable:
            fh = self.fs.open(f"{d}/manifest.json", "r+")
            self.fs.fsync(fh)
            self.fs.close(fh)
        return manifest

    # ---- restore ---------------------------------------------------------
    def latest_step(self) -> int | None:
        try:
            names = self.fs.listdir(self.root)
        except Exception:
            return None
        steps = []
        for n in names:
            if n.startswith("step_") and self.fs.exists(
                    f"{self.root}/{n}/manifest.json"):
                steps.append(int(n.split("_", 1)[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like=None):
        d = f"{self.root}/step_{step}"
        manifest = json.loads(self.fs.read_file(f"{d}/manifest.json"))
        flat = {}
        for key, info in manifest["leaves"].items():
            raw = self.fs.read_file(f"{d}/{key}.bin")
            flat[key] = np.frombuffer(raw, dtype=info["dtype"]).reshape(
                info["shape"])
        if like is None:
            return flat
        # rebuild into the structure of `like`
        leaves_like = jax.tree_util.tree_flatten_with_path(like)[0]
        rebuilt = []
        for path, leaf in leaves_like:
            key = ".".join(_key_str(k) for k in path)
            arr = flat[key]
            rebuilt.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype")
                           else arr)
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, rebuilt)

    def delete(self, step: int) -> None:
        d = f"{self.root}/step_{step}"
        for name in self.fs.listdir(d):
            self.fs.unlink(f"{d}/{name}")
        self.fs.rmdir(d)
