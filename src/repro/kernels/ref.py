"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: every kernel sweep in
tests/test_kernels.py asserts allclose against these, and they double as the
`impl="jnp"` execution path used on CPU (dry-run) and for backward passes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, q_offset: int = 0
                    ) -> jax.Array:
    """Multi-head attention with GQA, causal masking and optional sliding
    window.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
    `q_offset` places query i at absolute position i + q_offset (decode with
    a KV cache).  window = W keeps keys j with  pos_i - W < j <= pos_i.
    Returns (B, Hq, Sq, D) in q.dtype; math in float32.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    vf = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    pos_q = jnp.arange(sq) + q_offset
    pos_k = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= pos_k[None, :] <= pos_q[:, None]
    if window is not None:
        mask &= pos_k[None, :] > pos_q[:, None] - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows (can happen with tiny windows) produce uniform
    # garbage from softmax; zero them like the kernel does
    any_valid = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    out = jnp.where(any_valid, out, 0.0)
    return out.astype(q.dtype)


def flash_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                            causal: bool = True, window: int | None = None,
                            scale: float | None = None, q_offset: int = 0,
                            block_k: int = 512) -> jax.Array:
    """Flash-attention algorithm in pure jnp: lax.scan over key blocks with
    a running (m, l, acc) online softmax.  Mathematically identical to
    `flash_attention` but never materializes the (Sq, Sk) score matrix —
    this is the jnp execution path for long sequences (the XLA analogue of
    the Pallas kernel's VMEM tiling; §Perf#8)."""
    bsz, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    if sk % block_k:
        pad = block_k - sk % block_k
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nk = k.shape[2] // block_k
    qf = q.reshape(bsz, hkv, group * sq, d).astype(jnp.float32)
    kb = jnp.moveaxis(k.reshape(bsz, hkv, nk, block_k, d), 2, 0)
    vb = jnp.moveaxis(v.reshape(bsz, hkv, nk, block_k, d), 2, 0)
    pos_q = jnp.tile(jnp.arange(sq) + q_offset, group)      # grouped rows

    def body(carry, inp):
        m, l, acc, ki = carry[0], carry[1], carry[2], carry[3]
        kblk, vblk = inp
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kblk.astype(jnp.float32)) \
            * scale
        pos_k = ki * block_k + jnp.arange(block_k)
        mask = pos_k[None, :] < sk
        if causal:
            mask &= pos_k[None, :] <= pos_q[:, None]
        if window is not None:
            mask &= pos_k[None, :] > pos_q[:, None] - window
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1)
        # bf16 probabilities for the PV matmul (f32 accumulation): halves
        # the dominant transient of long prefills
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc, ki + 1), None

    m0 = jnp.full((bsz, hkv, group * sq), -1e30, jnp.float32)
    l0 = jnp.zeros((bsz, hkv, group * sq), jnp.float32)
    a0 = jnp.zeros((bsz, hkv, group * sq, d), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.int32(0)),
                                     (kb, vb))
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    out = jnp.where((l == 0.0)[..., None], 0.0, out)
    return out.reshape(bsz, hq, sq, d).astype(q.dtype)


def ssd_scan(x: jax.Array, loga: jax.Array, b: jax.Array, c: jax.Array,
             h0: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Mamba2 SSD recurrence (state-space dual form), naive time scan.

    x:    (B, S, H, P)   per-head inputs
    loga: (B, S, H)      log decay  (log a_t, a_t in (0,1])
    b:    (B, S, H, N)   input projection onto the state
    c:    (B, S, H, N)   state readout
    h0:   (B, H, N, P)   optional initial state

    Recurrence:  h_t = a_t * h_{t-1} + b_t ⊗ x_t ;   y_t = c_t · h_t.
    Returns (y (B,S,H,P) in x.dtype, h_final (B,H,N,P) float32).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    xf = x.astype(jnp.float32)
    af = jnp.exp(loga.astype(jnp.float32))
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp       # (B,H,P), (B,H), (B,H,N), (B,H,N)
        h = a_t[..., None, None] * h + jnp.einsum("bhn,bhp->bhnp", b_t, x_t)
        y = jnp.einsum("bhn,bhnp->bhp", c_t, h)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(af, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)         # (B,S,H,P)
    return y.astype(x.dtype), h_final


def ssd_scan_chunked(x: jax.Array, loga: jax.Array, b: jax.Array,
                     c: jax.Array, chunk: int = 128
                     ) -> tuple[jax.Array, jax.Array]:
    """SSD block decomposition in pure jnp (same math as the Pallas
    kernel): intra-chunk work is batched matmuls; the inter-chunk scan
    carries only the (B,H,N,P) state per chunk boundary — the per-timestep
    scan saved S× that for backward (592 GiB/dev for jamba train_4k,
    §Perf#8)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = max(1, min(chunk, S))
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xf = x.astype(jnp.float32).reshape(B, nc, chunk, H, P)
    lf = loga.astype(jnp.float32).reshape(B, nc, chunk, H)
    bf = b.astype(jnp.float32).reshape(B, nc, chunk, H, N)
    cf = c.astype(jnp.float32).reshape(B, nc, chunk, H, N)

    li = jnp.arange(chunk)
    causal = (li[None, :] <= li[:, None])[None, None]   # (1,1,L,L)

    def body(h, inp):
        xc, lc, bc, cc = inp      # (B,L,H,P), (B,L,H), (B,L,H,N), (B,L,H,N)
        cum = jnp.cumsum(lc, axis=1)                    # (B,L,H)
        total = cum[:, -1]                              # (B,H)
        gmat = jnp.einsum("blhn,bmhn->bhlm", cc, bc)    # (B,H,L,L)
        decay = jnp.exp(cum[:, :, None] - cum[:, None]
                        ).transpose(0, 3, 1, 2)          # (B,H,L,L)
        y = jnp.einsum("bhlm,bmhp->blhp", gmat * jnp.where(causal, decay,
                                                           0.0), xc)
        y += jnp.einsum("blhn,blh,bhnp->blhp", cc, jnp.exp(cum), h)
        w = jnp.exp(total[:, None] - cum)               # (B,L,H)
        h_new = jnp.exp(total)[..., None, None] * h \
            + jnp.einsum("blhn,blh,blhp->bhnp", bc, w, xc)
        return h_new, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    per_chunk = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(lf, 1, 0),
                 jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    h_final, ys = jax.lax.scan(jax.checkpoint(body), h0, per_chunk)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y.astype(x.dtype), h_final
