"""Flash attention Pallas TPU kernel (causal GQA + sliding window).

TPU adaptation notes (vs the CUDA flash-attention the literature assumes):

* tiling is BlockSpec-driven: Q tiles of (block_q, d) stream through VMEM
  while K/V tiles of (block_k, d) revisit; the MXU consumes (128, d)×(d, 128)
  matmuls, so block sizes default to 128 and d is the lane dimension;
* the online-softmax running state (m, l, acc) lives in VMEM scratch and is
  carried across the *sequential* innermost grid dimension (TPU grids are
  lexicographically sequential, which replaces the CUDA shared-memory
  reduction);
* GQA is expressed in the K/V index_map (query head h reads KV head
  h // group) — no materialized repeat, no extra HBM traffic;
* fully-masked (q, k) tiles are skipped with pl.when — with causal masking
  this halves the work, and with sliding windows it bounds it by
  O(window · seq).

Layouts: q (BH, Sq, D); k, v (BHkv, Sk, D).  All math float32 in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, causal: bool, window: int | None,
               block_q: int, block_k: int, sq: int, sk: int,
               q_offset: int) -> None:
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # absolute positions of this tile
    q_lo = qi * block_q + q_offset            # first query abs position
    k_lo = ki * block_k

    # tile-level relevance: causal keeps k_lo <= q_hi; window keeps
    # k_hi > q_lo - window
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k_lo <= q_lo + (block_q - 1)
    if window is not None:
        relevant &= (k_lo + block_k - 1) > (q_lo - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)      # (block_q, d)
        k = k_ref[0].astype(jnp.float32)      # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        # zero padded K/V rows: beyond-bounds block tails hold garbage, and
        # 0 * NaN would poison the p@v accumulation
        valid_k = (k_lo + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < sk
        k = jnp.where(valid_k, k, 0.0)
        v = jnp.where(valid_k, v, 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 0)
        cols = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                               (block_q, block_k), 1)
        mask = cols < sk                       # key tail padding
        mask &= (rows - q_offset) < sq         # query tail padding
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                    # (block_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           scale: float | None = None, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) -> (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    block_q = max(1, min(block_q, sq))
    block_k = max(1, min(block_k, sk))
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)

    qr = q.reshape(b * hq, sq, d)
    kr = k.reshape(b * hkv, sk, d)
    vr = v.reshape(b * hkv, sk, d)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        return ((bh // hq) * hkv + (bh % hq) // group, ki, 0)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, sq=sq, sk=sk, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), q_map),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),      # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),      # running sum l
            pltpu.VMEM((block_q, d), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, hq, sq, d)
