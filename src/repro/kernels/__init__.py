"""Pallas TPU kernels for the framework's compute hot-spots.

The paper (a storage-systems paper) has no kernel-level contribution; these
kernels serve the surrounding training/serving framework per DESIGN.md §7:

* flash_attention — causal GQA + sliding-window attention (hot-spot of 9/10
  assigned architectures),
* ssd_scan — Mamba2 chunked state-space-dual scan (mamba2-370m, jamba).

Each has a pure-jnp oracle in ref.py and a jit'd dispatch wrapper in ops.py.
"""

from .ops import flash_attention, ssd_scan

__all__ = ["flash_attention", "ssd_scan"]
