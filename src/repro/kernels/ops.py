"""Jit'd kernel wrappers with implementation dispatch.

impl ∈ {"jnp", "pallas", "pallas_interpret"}:

* "jnp"              — the pure-jnp oracle (ref.py).  Used on CPU, in the
                       multi-pod dry-run (identical math and FLOPs), and as
                       the backward pass.
* "pallas"           — the TPU kernel (compiled; target hardware only).
* "pallas_interpret" — the TPU kernel body executed in Python on CPU;
                       correctness validation in tests.

Differentiability: the Pallas paths are wrapped in jax.custom_vjp with a
recompute backward derived from the oracle — forward runs the kernel, the
backward re-derives gradients from the jnp reference (flash-attention-style
recompute; the dedicated backward kernels are listed as future work in
DESIGN.md §Kernels).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention_pallas
from .ssd_scan import ssd_scan_pallas

IMPLS = ("jnp", "pallas", "pallas_interpret")


# =========================================================================
# flash attention
# =========================================================================
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _fa_pallas(q, k, v, causal, window, scale, q_offset, interpret):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  scale=scale, q_offset=q_offset,
                                  interpret=interpret)


def _fa_fwd(q, k, v, causal, window, scale, q_offset, interpret):
    out = _fa_pallas(q, k, v, causal, window, scale, q_offset, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, window, scale, q_offset, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.flash_attention(
            q_, k_, v_, causal=causal, window=window, scale=scale,
            q_offset=q_offset), q, k, v)
    return vjp(g)


_fa_pallas.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, q_offset: int = 0,
                    impl: str = "jnp") -> jax.Array:
    assert impl in IMPLS, impl
    if impl == "jnp":
        # short sequences: direct softmax; long: the chunked flash
        # algorithm in jnp (never materializes the score matrix)
        if k.shape[2] <= 2048:
            return ref.flash_attention(q, k, v, causal=causal,
                                       window=window, scale=scale,
                                       q_offset=q_offset)
        return ref.flash_attention_chunked(q, k, v, causal=causal,
                                           window=window, scale=scale,
                                           q_offset=q_offset)
    return _fa_pallas(q, k, v, causal, window, scale, q_offset,
                      impl == "pallas_interpret")


# =========================================================================
# SSD scan
# =========================================================================
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _ssd_pallas(x, loga, b, c, chunk, interpret):
    return ssd_scan_pallas(x, loga, b, c, chunk=chunk, interpret=interpret)


def _ssd_fwd(x, loga, b, c, chunk, interpret):
    out = _ssd_pallas(x, loga, b, c, chunk, interpret)
    return out, (x, loga, b, c)


def _ssd_bwd(chunk, interpret, res, g):
    x, loga, b, c = res
    _, vjp = jax.vjp(lambda x_, l_, b_, c_: ref.ssd_scan(x_, l_, b_, c_),
                     x, loga, b, c)
    return vjp(g)


_ssd_pallas.defvjp(_ssd_fwd, _ssd_bwd)


def ssd_scan(x: jax.Array, loga: jax.Array, b: jax.Array, c: jax.Array, *,
             chunk: int = 128, impl: str = "jnp"
             ) -> tuple[jax.Array, jax.Array]:
    assert impl in IMPLS, impl
    if impl == "jnp":
        # chunked SSD (same block decomposition as the kernel): the naive
        # time scan saves S per-step states for backward
        if x.shape[1] % max(1, min(chunk, x.shape[1])) == 0:
            return ref.ssd_scan_chunked(x, loga, b, c,
                                        chunk=min(chunk, x.shape[1]))
        return ref.ssd_scan(x, loga, b, c)
    return _ssd_pallas(x, loga, b, c, chunk, impl == "pallas_interpret")
