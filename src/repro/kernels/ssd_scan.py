"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

The SSD block decomposition (Dao & Gu, 2024) splits the time axis into
chunks of length L.  Within a chunk the recurrence is a masked, decay-
weighted attention-like matmul (MXU-friendly); across chunks a tiny (N, P)
state is carried.  TPU adaptation:

* the chunk axis is the innermost (sequential) grid dimension, so the
  carried state h lives in VMEM scratch — the TPU analogue of the CUDA
  implementation's inter-block state passing through global memory;
* the three matmuls per chunk — G = C Bᵀ (L×L), Y_intra = (G ∘ D) X and the
  state update Bᵀ_w X — are all MXU matmuls; with L = N = P = 128 tiles the
  kernel is compute-bound rather than memory-bound;
* decay products use log-space cumulative sums for stability (exp of
  differences instead of products of many a_t < 1).

Layouts: x (BH, S, P); loga (BH, S); b, c (BH, S, N).  float32 math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, loga_ref, b_ref, c_ref, y_ref, hfin_ref, h_scr, *,
                chunk: int) -> None:
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (L, P)
    loga = loga_ref[0].astype(jnp.float32)    # (L,)
    bmat = b_ref[0].astype(jnp.float32)       # (L, N)
    cmat = c_ref[0].astype(jnp.float32)       # (L, N)
    h = h_scr[...]                            # (N, P)

    cum = jnp.cumsum(loga)                    # cum[i] = sum_{t<=i} log a_t
    total = cum[-1]

    # intra-chunk: y_i += sum_{j<=i} (c_i · b_j) exp(cum_i - cum_j) x_j
    gmat = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    dmask = jnp.where(lj <= li, decay, 0.0)
    y = jax.lax.dot_general(gmat * dmask, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y_i += exp(cum_i) * (c_i · h_prev)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, h, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: h = exp(total) h_prev + sum_j exp(total - cum_j) b_j x_jᵀ
    w = jnp.exp(total - cum)                  # (L,)
    h_new = jnp.exp(total) * h + jax.lax.dot_general(
        bmat * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)   # (N, P)
    h_scr[...] = h_new
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _fin():
        hfin_ref[0] = h_new.astype(hfin_ref.dtype)


def ssd_scan_pallas(x: jax.Array, loga: jax.Array, b: jax.Array,
                    c: jax.Array, *, chunk: int = 128,
                    interpret: bool = False
                    ) -> tuple[jax.Array, jax.Array]:
    """x (B,S,H,P), loga (B,S,H), b/c (B,S,H,N) -> (y (B,S,H,P), h (B,H,N,P)).

    S must be a multiple of `chunk` (callers pad; the model layer pads)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = max(1, min(chunk, S))
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    xr = jnp.moveaxis(x, 2, 1).reshape(B * H, S, P)
    lr = jnp.moveaxis(loga, 2, 1).reshape(B * H, S)
    br = jnp.moveaxis(b, 2, 1).reshape(B * H, S, N)
    cr = jnp.moveaxis(c, 2, 1).reshape(B * H, S, N)

    def seq_map(bh, ci):
        return (bh, ci, 0)

    def vec_map(bh, ci):
        return (bh, ci)

    def fin_map(bh, ci):
        return (bh, 0, 0)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, hfin = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), seq_map),
            pl.BlockSpec((1, chunk), vec_map),
            pl.BlockSpec((1, chunk, N), seq_map),
            pl.BlockSpec((1, chunk, N), seq_map),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), seq_map),
            pl.BlockSpec((1, N, P), fin_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xr, lr, br, cr)
    y = jnp.moveaxis(y.reshape(B, H, S, P), 1, 2)
    return y, hfin.reshape(B, H, N, P)
