from .pipeline import TokenPipeline, synth_corpus_to_cos

__all__ = ["TokenPipeline", "synth_corpus_to_cos"]
