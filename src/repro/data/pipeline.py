"""Tokenized training-data pipeline reading through objcache.

Corpus layout in the bucket: `<root>/shard_<i>.bin` files of int32 tokens.
The pipeline streams fixed-length sequences with cross-shard continuation,
deterministic shard order per epoch (seeded permutation), and relies on the
objcache client's chunk readahead for prefetch — a second read of the same
epoch hits the cluster-local (or node-local) cache tier, which is the
paper's Fig. 9 read path applied to training input.
"""

from __future__ import annotations

import numpy as np

from ..core.fs import ObjcacheFS


def synth_corpus_to_cos(cos, bucket: str, root: str, *, n_shards: int,
                        tokens_per_shard: int, vocab: int,
                        seed: int = 0) -> int:
    """Generate a deterministic synthetic corpus directly into COS.

    Tokens are Zipf-distributed (natural-language-like skew), so a model
    can actually reduce loss below ln(vocab) by learning the unigram (and
    the repeat-bigram structure injected below)."""
    rng = np.random.default_rng(seed)
    total = 0
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    for i in range(n_shards):
        toks = rng.choice(vocab, size=tokens_per_shard, p=probs
                          ).astype(np.int32)
        # inject learnable bigram structure: every 3rd token repeats
        toks[2::3] = toks[1::3][:len(toks[2::3])]
        cos.put_object(bucket, f"{root.strip('/')}/shard_{i}.bin",
                       toks.tobytes())
        total += tokens_per_shard
    return total


class TokenPipeline:
    def __init__(self, fs: ObjcacheFS, root: str, *, batch: int,
                 seq_len: int, seed: int = 0) -> None:
        self.fs = fs
        self.root = root.rstrip("/")
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        names = [n for n in fs.listdir(self.root) if n.endswith(".bin")]
        self.shards = sorted(names)
        if not self.shards:
            raise ValueError(f"no shards under {root}")

    def _epoch_order(self, epoch: int) -> list[str]:
        rng = np.random.default_rng(self.seed + epoch)
        order = list(self.shards)
        rng.shuffle(order)
        return order

    def batches(self, epoch: int = 0):
        """Yields dict(tokens (B, S), labels (B, S)) int32 arrays."""
        need = self.batch * (self.seq_len + 1)
        buf = np.empty((0,), np.int32)
        for name in self._epoch_order(epoch):
            raw = self.fs.read_file(f"{self.root}/{name}")
            buf = np.concatenate([buf, np.frombuffer(raw, np.int32)])
            while len(buf) >= need:
                take, buf = buf[:need], buf[need:]
                mat = take.reshape(self.batch, self.seq_len + 1)
                yield {"tokens": mat[:, :-1].copy(),
                       "labels": mat[:, 1:].copy()}
