"""ObjcacheFS — the POSIX-like surface applications mount (§3.2).

Path semantics follow the paper: a COS key ``a/b/c.txt`` in bucket
``bucketA`` appears as ``/bucketA/a/b/c.txt``; a key with a trailing ``/``
is a directory.  The filesystem object wraps one `ObjcacheClient` (one per
node) and implements open/read/write/fsync/close plus the namespace calls,
honoring the configured consistency model:

* strict (read-after-write): `write()` stages chunks and runs the flush
  transaction before returning; `read()` always consults cluster state.
* weak (close-to-open): `write()` buffers locally up to 128 KB; buffered
  data commits at flush pressure, fsync(), or close(); reads may serve from
  the node-local page cache; attributes validate once at open().

`fsync()` additionally runs the persisting transaction (Fig. 8) so the file
is durable in external storage when it returns.  `close()` only commits to
cluster-local cache — upload to COS happens via the background flush
(write-back, §5.2), which is what Fig. 12 measures against S3FS's
synchronous upload-on-close.
"""

from __future__ import annotations

import posixpath

from .client import ObjcacheClient, _Handle
from .types import Errno, FSError, InodeKind, ROOT_INODE


def _norm(path: str) -> list[str]:
    path = posixpath.normpath("/" + path.strip())
    return [p for p in path.split("/") if p]


class ObjcacheFS:
    def __init__(self, client: ObjcacheClient) -> None:
        self.client = client

    # =====================================================================
    # path resolution
    # =====================================================================
    def resolve(self, path: str) -> int:
        ino = ROOT_INODE
        for name in _norm(path):
            ino = self.client.lookup(ino, name)
        return ino

    def resolve_parent(self, path: str) -> tuple[int, str]:
        parts = _norm(path)
        if not parts:
            raise FSError(Errno.EINVAL, "root has no parent")
        ino = ROOT_INODE
        for name in parts[:-1]:
            ino = self.client.lookup(ino, name)
        return ino, parts[-1]

    def _cos_target(self, path: str) -> tuple[str | None, str | None]:
        """Map a path to its (bucket, key) backing: the first component is a
        bucket-mount directory; the remainder is the object key."""
        parts = _norm(path)
        if not parts:
            return None, None
        try:
            bino = self.client.lookup(ROOT_INODE, parts[0])
            battr = self.client.getattr(bino, cached_ok=True)
        except FSError:
            return None, None
        bucket = battr.get("cos_bucket")
        if bucket is None:
            return None, None
        key = "/".join(parts[1:])
        return bucket, key

    # =====================================================================
    # namespace ops
    # =====================================================================
    def stat(self, path: str) -> dict:
        return self.client.getattr(self.resolve(path))

    def exists(self, path: str) -> bool:
        try:
            self.resolve(path)
            return True
        except FSError as e:
            if e.errno in (Errno.ENOENT, Errno.ENOTDIR):
                return False
            raise

    def listdir(self, path: str) -> list[str]:
        ino = self.resolve(path)
        return sorted(self.client.readdir(ino))

    def mkdir(self, path: str) -> int:
        parent, name = self.resolve_parent(path)
        bucket, key = self._cos_target(path)
        cos_key = (key + "/") if (bucket and key) else None
        return self.client.create(parent, name, InodeKind.DIR, bucket, cos_key)

    def makedirs(self, path: str) -> None:
        parts = _norm(path)
        for i in range(1, len(parts) + 1):
            sub = "/" + "/".join(parts[:i])
            if not self.exists(sub):
                self.mkdir(sub)

    def unlink(self, path: str) -> None:
        parent, name = self.resolve_parent(path)
        ino = self.client.lookup(parent, name)
        self.client.unlink(parent, name, ino)

    def rmdir(self, path: str) -> None:
        self.unlink(path)

    def rename(self, src: str, dst: str) -> None:
        sp, sn = self.resolve_parent(src)
        dp, dn = self.resolve_parent(dst)
        ino = self.client.lookup(sp, sn)
        if self.exists(dst):
            self.unlink(dst)
        _, new_key = self._cos_target(dst)
        self.client.rename(sp, sn, dp, dn, ino, new_key)

    def truncate(self, path: str, size: int) -> None:
        self.client.truncate(self.resolve(path), size)

    # =====================================================================
    # file handles
    # =====================================================================
    def open(self, path: str, mode: str = "r") -> int:
        """Modes: "r" read, "w" create/truncate, "a" append, "r+" read/write."""
        writable = any(m in mode for m in ("w", "a", "+"))
        created = False
        try:
            ino = self.resolve(path)
            if "w" in mode:
                self.client.truncate(ino, 0)
        except FSError as e:
            if e.errno != Errno.ENOENT or not writable or "r" == mode:
                raise
            parent, name = self.resolve_parent(path)
            bucket, key = self._cos_target(path)
            ino = self.client.create(parent, name, InodeKind.FILE,
                                     bucket, key or None)
            created = True
        attr = self.client.getattr(ino, cached_ok=False)  # close-to-open check
        if attr["kind"] == int(InodeKind.DIR):
            raise FSError(Errno.EISDIR, path)
        fh = next(self.client._fh)
        h = _Handle(fh=fh, ino=ino, path=path, writable=writable,
                    size_hint=0 if "w" in mode else attr["size"],
                    appending_new=created or "w" in mode)
        self.client.handles[fh] = h
        return fh

    def _h(self, fh: int) -> _Handle:
        h = self.client.handles.get(fh)
        if h is None:
            raise FSError(Errno.EINVAL, f"bad fh {fh}")
        return h

    # =====================================================================
    # read / write
    # =====================================================================
    def write(self, fh: int, off: int, data: bytes) -> int:
        h = self._h(fh)
        if not h.writable:
            raise FSError(Errno.EINVAL, "read-only handle")
        cl = self.client
        if cl.cfg.consistency == "strict":
            # read-after-write: reflect immediately in cluster-local cache
            seq = cl.next_seq()
            staged = cl.write_chunks(h.ino, off, data, seq)
            new_size = max(h.size_hint, off + len(data))
            cl.flush_write(h.ino, staged, new_size, seq)
            h.size_hint = new_size
            cl.invalidate_ino(h.ino)
        else:
            h.buffer.append((off, bytes(data)))
            h.buffered_bytes += len(data)
            h.size_hint = max(h.size_hint, off + len(data))
            if h.buffered_bytes >= cl.cfg.write_buffer_bytes:
                self._flush_buffer(h)
        return len(data)

    def append(self, fh: int, data: bytes) -> int:
        h = self._h(fh)
        return self.write(fh, h.size_hint, data)

    def _flush_buffer(self, h: _Handle) -> None:
        if not h.buffer:
            return
        cl = self.client
        # coalesce *consecutive* adjacent writes into runs (§6.2 batching);
        # temporal order must be preserved — later writes win on overlap,
        # so no reordering beyond merging a write that exactly extends the
        # previous one
        runs: list[tuple[int, bytearray]] = []
        for off, data in h.buffer:
            if runs and runs[-1][0] + len(runs[-1][1]) == off:
                runs[-1][1].extend(data)
            else:
                runs.append((off, bytearray(data)))
        seq = cl.next_seq()
        staged_all: dict[int, list[str]] = {}
        for off, data in runs:
            for coff, ids in cl.write_chunks(h.ino, off, bytes(data), seq):
                staged_all.setdefault(coff, []).extend(ids)
            seq = cl.next_seq()
        cl.flush_write(h.ino, sorted(staged_all.items()), h.size_hint, seq)
        h.buffer.clear()
        h.buffered_bytes = 0
        cl.invalidate_ino(h.ino)

    def read(self, fh: int, off: int, length: int) -> bytes:
        h = self._h(fh)
        cl = self.client
        if cl.cfg.consistency == "strict":
            meta = cl.getattr(h.ino, cached_ok=False)
        else:
            if h.buffer:
                self._flush_buffer(h)  # read-your-own-writes within a handle
            meta = cl.getattr(h.ino, cached_ok=True)
        return cl.read_range(h.ino, off, length, meta, handle=h)

    def fsync(self, fh: int) -> None:
        h = self._h(fh)
        self._flush_buffer(h)
        self.client.fsync_ino(h.ino)

    def close(self, fh: int) -> None:
        h = self.client.handles.pop(fh, None)
        if h is None:
            return
        if h.buffer:
            self.client.handles[fh] = h  # restore for flush path
            try:
                self._flush_buffer(h)
            finally:
                self.client.handles.pop(fh, None)
        h.stream_cache.clear()

    # =====================================================================
    # convenience
    # =====================================================================
    def write_file(self, path: str, data: bytes) -> None:
        fh = self.open(path, "w")
        try:
            self.write(fh, 0, data)
        finally:
            self.close(fh)

    def read_file_range(self, path: str, off: int, length: int) -> bytes:
        """Positioned whole-range read: open, read exactly [off, off+length)
        (short only at EOF), close.  The block-granular read path — callers
        with a segment table (e.g. `serving/kvstore.py` fetching one layer's
        KV block) pay only for the bytes they name, while the client's
        chunk-granular readahead still batches adjacent segments."""
        fh = self.open(path, "r")
        try:
            out = bytearray()
            pos, end = off, off + length
            while pos < end:
                blk = self.read(fh, pos, min(1 << 22, end - pos))
                if not blk:
                    break
                out += blk
                pos += len(blk)
            return bytes(out)
        finally:
            self.close(fh)

    def read_file(self, path: str) -> bytes:
        fh = self.open(path, "r")
        try:
            size = self.client.getattr(self._h(fh).ino,
                                       cached_ok=True)["size"]
            out = bytearray()
            pos = 0
            while pos < size:
                blk = self.read(fh, pos, min(1 << 22, size - pos))
                if not blk:
                    break
                out += blk
                pos += len(blk)
            return bytes(out)
        finally:
            self.close(fh)

    def walk_files(self, path: str = "/") -> list[str]:
        out: list[str] = []
        stack = [path.rstrip("/") or "/"]
        while stack:
            cur = stack.pop()
            for name in self.listdir(cur):
                child = (cur.rstrip("/") + "/" + name)
                st = self.stat(child)
                if st["kind"] == int(InodeKind.DIR):
                    stack.append(child)
                else:
                    out.append(child)
        return sorted(out)
