"""Consistent hashing (§4.2).

Deterministic (blake2b-based) ring with optional virtual nodes for balance.
A key is owned by the node whose hash is the largest value <= hash(key)
(i.e. the key's *predecessor* on the ring, matching the paper's wording that
metadata/chunk owners are "predecessor nodes").  Also provides the migration
set computation used at join/leave (§4.3): a node join affects only the
ranges its virtual points split.
"""

from __future__ import annotations

import bisect
import hashlib
from dataclasses import dataclass


def h64(key: str) -> int:
    return int.from_bytes(hashlib.blake2b(key.encode(), digest_size=8).digest(),
                          "big")


@dataclass(frozen=True)
class RingPoint:
    hash: int
    node: str


class HashRing:
    def __init__(self, nodes: list[str] | None = None, vnodes: int = 32) -> None:
        self.vnodes = vnodes
        self._points: list[RingPoint] = []
        self._hashes: list[int] = []
        self._nodes: set[str] = set()
        for n in nodes or []:
            self.add_node(n)

    # ---- membership ----------------------------------------------------------
    def nodes(self) -> list[str]:
        return sorted(self._nodes)

    def __contains__(self, node: str) -> bool:
        return node in self._nodes

    def __len__(self) -> int:
        return len(self._nodes)

    def _vpoints(self, node: str) -> list[int]:
        return [h64(f"{node}#{i}") for i in range(self.vnodes)]

    def add_node(self, node: str) -> None:
        if node in self._nodes:
            return
        self._nodes.add(node)
        for hv in self._vpoints(node):
            idx = bisect.bisect_left(self._hashes, hv)
            self._hashes.insert(idx, hv)
            self._points.insert(idx, RingPoint(hv, node))

    def remove_node(self, node: str) -> None:
        if node not in self._nodes:
            return
        self._nodes.discard(node)
        keep = [(p, h) for p, h in zip(self._points, self._hashes)
                if p.node != node]
        self._points = [p for p, _ in keep]
        self._hashes = [h for _, h in keep]

    def copy(self) -> "HashRing":
        r = HashRing(vnodes=self.vnodes)
        for n in self._nodes:
            r.add_node(n)
        return r

    # ---- lookup ---------------------------------------------------------------
    def node_for(self, key: str) -> str:
        if not self._points:
            raise RuntimeError("empty hash ring")
        hv = h64(key)
        # predecessor point: largest point hash <= hv, wrapping to the end
        idx = bisect.bisect_right(self._hashes, hv) - 1
        return self._points[idx].node  # idx == -1 wraps to last point

    # ---- migration math (§4.3) -------------------------------------------------
    @staticmethod
    def moved_keys(before: "HashRing", after: "HashRing",
                   keys: list[str]) -> dict[str, tuple[str, str]]:
        """Returns {key: (old_owner, new_owner)} for keys whose owner changes."""
        out = {}
        for k in keys:
            a, b = before.node_for(k), after.node_for(k)
            if a != b:
                out[k] = (a, b)
        return out
