"""Shared core types: inode ids, transaction ids, command enum, errors."""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import NamedTuple

ROOT_INODE = 1
CHUNK_SIZE_DEFAULT = 16 * 1024 * 1024  # paper's 16 MB


class TxId(NamedTuple):
    """§4.5: unique transaction id = (ClientId, SeqNum, TxSeqNum).

    ClientId identifies the transaction client within a FUSE instance;
    SeqNum is the client's monotonic local clock; TxSeqNum is assigned by the
    coordinator so retried RPC series reuse the exact same id (idempotency).
    """

    client_id: int
    seq: int
    txseq: int

    def pretty(self) -> str:
        return f"tx({self.client_id}.{self.seq}.{self.txseq})"

    @property
    def age_key(self) -> tuple[int, int]:
        """Wait-die age: lower sorts *older*.  SeqNum first (a client's
        monotonic local clock approximates start order), client id breaks
        ties deterministically.  TxSeqNum is excluded: retries of one
        operation mint a fresh TxSeqNum but keep (ClientId, SeqNum), so a
        died transaction keeps its age and eventually becomes the oldest —
        the classic wait-die no-starvation argument."""
        return (self.seq, self.client_id)


class Cmd(enum.IntEnum):
    """Raft state-machine command ids (paper: 72 variants; we keep the full
    control set needed by the protocol — prepare/commit/abort per object kind
    plus FS-level and cluster-level records)."""

    # transaction control
    TX_PREPARE_META = 1
    TX_PREPARE_CHUNK = 2
    TX_PREPARE_DIR = 3
    TX_PREPARE_NODELIST = 4
    TX_COMMIT = 5
    TX_ABORT = 6
    # coordinator-side durable decisions (2PC recovery, §4.4 last para)
    TX_COORD_BEGIN = 7
    TX_COORD_DECIDE_COMMIT = 8
    TX_COORD_DECIDE_ABORT = 9
    # single-node fast path (no 2PC; §4.4 "we do not use this protocol for
    # updates at a single node")
    LOCAL_META_UPDATE = 10
    LOCAL_CHUNK_WRITE = 11
    LOCAL_DIR_UPDATE = 12
    LOCAL_CHUNK_COMMIT = 13   # single-node fast-path promote of staged writes
    EVICT_META = 14           # drop clean / migrated-away metadata
    EVICT_CHUNK = 15
    # data-path records
    CHUNK_STAGE = 20          # outstanding write staged to second-level log
    CHUNK_FILL_FROM_COS = 21  # materialized a chunk range from external storage
    # persistence (fsync / MPU) records — black dots in Fig. 8
    MPU_BEGIN_RECORDED = 30
    MPU_COMMITTED = 31
    PUT_OBJECT_DONE = 32
    DIRTY_CLEARED_CHUNK = 33
    DIRTY_CLEARED_META = 34
    COS_DELETE_DONE = 35
    MPU_ABORTED = 36          # upload aborted (runtime or orphan recovery)
    # cluster reconfiguration
    NODE_JOIN = 40
    NODE_LEAVE = 41
    MIGRATE_RECV_META = 42
    MIGRATE_RECV_CHUNK = 43
    MIGRATE_RECV_DIR = 44
    # maintenance
    SNAPSHOT = 50


class Errno(enum.IntEnum):
    OK = 0
    ENOENT = 2
    EIO = 5
    EAGAIN = 11       # shed by per-tenant admission control at the RPC fabric
    EEXIST = 17
    ENOTDIR = 20
    EISDIR = 21
    EINVAL = 22
    ENOSPC = 28
    ESTALE = 116      # node-list version mismatch (§4.3)
    ETIMEDOUT = 110
    ECONFLICT = 125   # lock conflict -> coordinator aborts, client retries
    ENOTEMPTY = 39


class FSError(Exception):
    def __init__(self, errno: Errno, msg: str = "") -> None:
        super().__init__(f"{errno.name}: {msg}")
        self.errno = errno


class StaleLeaseError(FSError):
    """A request carried a lease epoch that a committed mutation has since
    bumped (ESTALE).  Distinct from the node-list ESTALE of §4.3: the client
    drops the cached lease and re-fetches, without re-pulling the node list."""

    def __init__(self, ino: int, client_epoch: int, server_epoch: int) -> None:
        super().__init__(Errno.ESTALE,
                         f"lease on ino {ino}: epoch {client_epoch} != "
                         f"{server_epoch}")
        self.ino = ino


class AdmissionError(FSError):
    """The RPC fabric shed this envelope: the caller's tenant is over its
    token-bucket rate and the bounded admission queue is full (EAGAIN).
    Open-loop load generators record the shed and move on; a foreground
    application could retry after `retry_after_s` of virtual time."""

    def __init__(self, tenant: str, method: str, retry_after_s: float) -> None:
        super().__init__(Errno.EAGAIN,
                         f"tenant {tenant!r} shed at {method} "
                         f"(retry in {retry_after_s:.6f}s)")
        self.tenant = tenant
        self.method = method
        self.retry_after_s = retry_after_s


class InodeKind(enum.IntEnum):
    FILE = 0
    DIR = 1


@dataclass
class InodeMeta:
    """On-disk inode metadata (§4.1)."""

    ino: int
    kind: InodeKind
    size: int = 0
    mode: int = 0o644
    mtime: float = 0.0
    version: int = 0          # bumped by every committed metadata update;
    dirty: bool = False       # guards async dirty-clear races (§5.2)
    deleted: bool = False
    # mapping to the physical key at external storage (bucket, key); kept in
    # the in-memory inode in the paper, persisted here for simplicity of replay
    cos_bucket: str | None = None
    cos_key: str | None = None
    # keys that must be deleted from COS at the next persisting transaction
    # (left behind by rename/unlink, §5.4)
    cos_old_keys: list[str] = field(default_factory=list)
    # directories are "special files with child inodes and names" (§4.1)
    children: dict[str, int] = field(default_factory=dict)
    nlink: int = 1
    # lazy COS namespace materialization (§3.2): set once the children of a
    # directory have been listed from external storage (load-once; §3.3 "does
    # not automatically check if the current cache is outdated")
    loaded: bool = False

    def clone(self) -> "InodeMeta":
        return InodeMeta(
            ino=self.ino, kind=self.kind, size=self.size, mode=self.mode,
            mtime=self.mtime, version=self.version, dirty=self.dirty,
            deleted=self.deleted, cos_bucket=self.cos_bucket,
            cos_key=self.cos_key, cos_old_keys=list(self.cos_old_keys),
            children=dict(self.children), nlink=self.nlink,
            loaded=self.loaded)

    def to_payload(self) -> dict:
        return {
            "ino": self.ino, "kind": int(self.kind), "size": self.size,
            "mode": self.mode, "mtime": self.mtime, "version": self.version,
            "dirty": self.dirty, "deleted": self.deleted,
            "cos_bucket": self.cos_bucket, "cos_key": self.cos_key,
            "cos_old_keys": list(self.cos_old_keys),
            "children": dict(self.children), "nlink": self.nlink,
            "loaded": self.loaded,
        }

    @staticmethod
    def from_payload(p: dict) -> "InodeMeta":
        return InodeMeta(
            ino=p["ino"], kind=InodeKind(p["kind"]), size=p["size"],
            mode=p["mode"], mtime=p["mtime"], version=p.get("version", 0),
            dirty=p["dirty"], deleted=p["deleted"],
            cos_bucket=p.get("cos_bucket"), cos_key=p.get("cos_key"),
            cos_old_keys=list(p.get("cos_old_keys", [])),
            children={k: int(v) for k, v in p.get("children", {}).items()},
            nlink=p.get("nlink", 1), loaded=p.get("loaded", False))


def chunk_key(ino: int, chunk_off: int) -> str:
    """§4.2: chunk 0 shares the metadata hash key (enables the single-
    participant PutObject fast path, §5.2); other chunks concatenate inode id
    and offset with '/'."""
    if chunk_off == 0:
        return str(ino)
    return f"{ino}/{chunk_off}"


def meta_key(ino: int) -> str:
    return str(ino)
