"""Transaction tables for the internal 2PC protocol (§4.4–4.5).

Each server keeps:

* a `LockTable` — per-object exclusive locks held by *prepared* transactions;
  prepare is all-or-nothing and non-blocking (a participant that cannot lock
  votes no, the coordinator aborts, the client retries), so there are no
  distributed deadlocks;
* a `TxTable` — prepared (redo-logged, not yet applied) transactions plus a
  bounded dedup map of completed transaction results, so a retried RPC series
  with the same TxId is idempotent (§4.5: "objcache detects a duplicated
  request [and] replies with old results as done in the Raft RPCs").

Both tables are *derived state*: they are reconstructed from the Raft log on
replay (PREPARE entries re-acquire locks; COMMIT/ABORT entries release them),
which is exactly what lets 2PC survive participant crashes (§4.4 last para).
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field

from .types import Cmd, TxId


@dataclass
class PreparedOp:
    """One redo-logged object mutation owned by this participant."""

    cmd: Cmd            # TX_PREPARE_META / _CHUNK / _DIR / _NODELIST
    payload: dict       # full redo image (applied at commit)


@dataclass
class PreparedTx:
    txid: TxId
    ops: list[PreparedOp] = field(default_factory=list)
    locked_keys: list[str] = field(default_factory=list)


class LockTable:
    def __init__(self) -> None:
        self._locks: dict[str, TxId] = {}

    def try_acquire(self, keys: list[str], txid: TxId) -> bool:
        """All-or-nothing; re-acquisition by the same TxId succeeds (retry)."""
        for k in keys:
            holder = self._locks.get(k)
            if holder is not None and holder != txid:
                return False
        for k in keys:
            self._locks[k] = txid
        return True

    def release(self, txid: TxId) -> None:
        for k in [k for k, h in self._locks.items() if h == txid]:
            del self._locks[k]

    def holder(self, key: str) -> TxId | None:
        return self._locks.get(key)

    def held_count(self) -> int:
        return len(self._locks)


class TxTable:
    """Prepared transactions + completed-result dedup window."""

    DEDUP_WINDOW = 4096

    def __init__(self) -> None:
        self.prepared: dict[TxId, PreparedTx] = {}
        self._completed: OrderedDict[tuple, str] = OrderedDict()

    # ---- prepared --------------------------------------------------------------
    def is_prepared(self, txid: TxId) -> bool:
        return txid in self.prepared

    def put_prepared(self, tx: PreparedTx) -> None:
        self.prepared[tx.txid] = tx

    def pop_prepared(self, txid: TxId) -> PreparedTx | None:
        return self.prepared.pop(txid, None)

    # ---- dedup -----------------------------------------------------------------
    def record_completed(self, txid: TxId, outcome: str) -> None:
        key = tuple(txid)
        self._completed[key] = outcome
        self._completed.move_to_end(key)
        while len(self._completed) > self.DEDUP_WINDOW:
            self._completed.popitem(last=False)

    def completed_outcome(self, txid: TxId) -> str | None:
        return self._completed.get(tuple(txid))


def txid_payload(txid: TxId) -> dict:
    return {"client_id": txid.client_id, "seq": txid.seq, "txseq": txid.txseq}


def txid_from_payload(p: dict) -> TxId:
    return TxId(p["client_id"], p["seq"], p["txseq"])
