"""Transaction tables for the internal 2PC protocol (§4.4–4.5).

Each server keeps:

* a `LockTable` — per-object exclusive locks held by *prepared* transactions.
  The paper's protocol is all-or-nothing vote-no on any conflict; this table
  additionally supports bounded FIFO *wait-die* queueing (`lock_mode=
  "waitdie"`): an older transaction that hits a conflict enqueues behind the
  holder (bounded queue) and is handed the lock when the holder releases,
  while a younger transaction dies immediately — the classic wait-die
  ordering, so deadlock freedom is preserved without global lock ordering;
* a `TxTable` — prepared (redo-logged, not yet applied) transactions plus a
  bounded dedup map of completed transaction results, so a retried RPC series
  with the same TxId is idempotent (§4.5: "objcache detects a duplicated
  request [and] replies with old results as done in the Raft RPCs").

Both tables are *derived state*: they are reconstructed from the Raft log on
replay (PREPARE entries re-acquire locks; COMMIT/ABORT entries release them).
Wait queues hold transactions that have *not* prepared (nothing logged yet),
so replay rebuilds holders and leaves queues empty; the waiters' coordinators
re-enqueue on retry with the same TxId — which is exactly what lets 2PC
survive participant crashes (§4.4 last para).
"""

from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field

from .types import Cmd, TxId


@dataclass
class PreparedOp:
    """One redo-logged object mutation owned by this participant."""

    cmd: Cmd            # TX_PREPARE_META / _CHUNK / _DIR / _NODELIST
    payload: dict       # full redo image (applied at commit)


@dataclass
class PreparedTx:
    txid: TxId
    ops: list[PreparedOp] = field(default_factory=list)
    locked_keys: list[str] = field(default_factory=list)


def _opkey(txid: TxId) -> tuple[int, int]:
    """Logical-operation identity: retries of one file operation reuse the
    same (client_id, seq) but get a fresh txseq, and the queue position /
    reservation must survive across attempts."""
    return (txid.client_id, txid.seq)


class LockTable:
    """Per-key exclusive locks with optional bounded wait-die queues.

    Queue membership and reservations are keyed by the logical operation
    (`(client_id, seq)`) rather than the full TxId: a retried attempt (new
    txseq, §4.5) claims the place — and the hand-off — its previous attempt
    earned.  A *reservation* is a lock handed to the head waiter when the
    previous holder released; the waiter's coordinator has not retried yet,
    so the reservation carries an expiry (`grant_t + reservation_ttl_s` on
    the sim clock) after which any acquirer may steal it — an abandoned
    waiter can never wedge a hot key."""

    def __init__(self, queue_depth: int = 4,
                 reservation_ttl_s: float = 1.0) -> None:
        self._locks: dict[str, TxId] = {}
        self.queue_depth = queue_depth
        self.reservation_ttl_s = reservation_ttl_s
        # key -> FIFO of waiting ops (wait-die: all waiters are older than
        # the holder they queued behind), as (client_id, seq) -> repr TxId
        self._queues: dict[str, deque[tuple[int, int]]] = {}
        self._waiters: dict[tuple[int, int], TxId] = {}
        # op -> expiry time of an unclaimed hand-off (reservation)
        self._reserved_until: dict[tuple[int, int], float] = {}

    # ---- acquisition -----------------------------------------------------------
    def _conflict(self, key: str, txid: TxId, now: float) -> TxId | None:
        """Current effective holder of `key` if it blocks `txid`."""
        holder = self._locks.get(key)
        if holder is None or _opkey(holder) == _opkey(txid):
            return None                    # free, ours, or our prior attempt
        exp = self._reserved_until.get(_opkey(holder))
        if exp is not None and now > exp:
            # expired reservation: the waiter never came back — steal it
            self._drop_holder(holder)
            return None
        return holder

    def try_acquire(self, keys: list[str], txid: TxId,
                    now: float = 0.0) -> bool:
        """Legacy all-or-nothing interface (vote-no on conflict); also claims
        a reservation held for `txid`.  Used by WAL replay and vote-no mode."""
        return self.acquire(keys, txid, now, wait_die=False) == "granted"

    def acquire(self, keys: list[str], txid: TxId, now: float,
                wait_die: bool = True) -> str:
        """All-or-nothing acquire; returns "granted" | "queued" | "die".

        wait-die on conflict: if `txid` is older than every blocking holder
        and each blocked key has queue space, enqueue (FIFO) and return
        "queued" — the release hand-off will grant the lock before the
        operation's retry (same client_id/seq) comes back to claim it.  A
        younger `txid` (or a full queue) returns "die" ("queued" and "die"
        both read as vote-no to the 2PC; the difference is whether the
        operation kept its place in line)."""
        op = _opkey(txid)
        blocked: list[tuple[str, TxId]] = []
        for k in keys:
            h = self._conflict(k, txid, now)
            if h is not None:
                blocked.append((k, h))
        if not blocked:
            for k in keys:
                self._locks[k] = txid
            self._reserved_until.pop(op, None)     # claimed in person
            self._unqueue(op)                      # no longer waiting anywhere
            return "granted"
        if not wait_die:
            return "die"
        for k, h in blocked:
            if not txid.age_key < h.age_key:
                return "die"                       # younger dies immediately
            q = self._queues.get(k)
            if q is not None and op not in q and len(q) >= self.queue_depth:
                return "die"                       # bounded queue is full
        for k, _h in blocked:
            q = self._queues.setdefault(k, deque())
            if op not in q:
                q.append(op)
        self._waiters[op] = txid
        return "queued"

    # ---- release / hand-off ----------------------------------------------------
    def _drop_holder(self, txid: TxId) -> None:
        self._reserved_until.pop(_opkey(txid), None)
        for k in [k for k, h in self._locks.items() if h == txid]:
            del self._locks[k]

    def _unqueue(self, op: tuple[int, int]) -> None:
        self._waiters.pop(op, None)
        for k in [k for k, q in self._queues.items() if op in q]:
            self._queues[k].remove(op)
            if not self._queues[k]:
                del self._queues[k]

    def release(self, txid: TxId, now: float = 0.0) -> None:
        """Free `txid`'s locks and hand each freed key to its oldest waiter
        as a reservation (claimed when the waiter's retry comes back)."""
        op = _opkey(txid)
        freed = [k for k, h in self._locks.items() if _opkey(h) == op]
        self._reserved_until.pop(op, None)
        for k in freed:
            del self._locks[k]
        self._unqueue(op)                          # also stop waiting
        for k in freed:
            q = self._queues.get(k)
            while q:
                wop = q.popleft()
                w = self._waiters.get(wop)
                if w is not None and self._conflict(k, w, now) is None:
                    self._locks[k] = w
                    self._reserved_until.setdefault(
                        wop, now + self.reservation_ttl_s)
                    break
            if q is not None and not q:
                del self._queues[k]

    # ---- introspection ---------------------------------------------------------
    def holder(self, key: str) -> TxId | None:
        return self._locks.get(key)

    def held_count(self) -> int:
        return len(self._locks)

    def queued(self, key: str) -> list[TxId]:
        return [self._waiters[op] for op in self._queues.get(key, ())
                if op in self._waiters]

    def queued_count(self) -> int:
        return sum(len(q) for q in self._queues.values())


class TxTable:
    """Prepared transactions + completed-result dedup window."""

    DEDUP_WINDOW = 4096

    def __init__(self) -> None:
        self.prepared: dict[TxId, PreparedTx] = {}
        self._completed: OrderedDict[tuple, str] = OrderedDict()

    # ---- prepared --------------------------------------------------------------
    def is_prepared(self, txid: TxId) -> bool:
        return txid in self.prepared

    def put_prepared(self, tx: PreparedTx) -> None:
        self.prepared[tx.txid] = tx

    def pop_prepared(self, txid: TxId) -> PreparedTx | None:
        return self.prepared.pop(txid, None)

    # ---- dedup -----------------------------------------------------------------
    def record_completed(self, txid: TxId, outcome: str) -> None:
        key = tuple(txid)
        self._completed[key] = outcome
        self._completed.move_to_end(key)
        while len(self._completed) > self.DEDUP_WINDOW:
            self._completed.popitem(last=False)

    def completed_outcome(self, txid: TxId) -> str | None:
        return self._completed.get(tuple(txid))


def txid_payload(txid: TxId) -> dict:
    return {"client_id": txid.client_id, "seq": txid.seq, "txseq": txid.txseq}


def txid_from_payload(p: dict) -> TxId:
    return TxId(p["client_id"], p["seq"], p["txseq"])
