"""Pipelined background write-back — the flusher behind §5.2 / Figs. 12-14.

`BackgroundFlusher` is the cluster's "expiration of dirty objects" engine.
Where the old `Cluster.tick_flush` threaded one virtual time `t` through
every dirty inode (each `coord_persist` waited for the previous one), the
flusher schedules persists *concurrently*: every coordinator is dispatched
through a bounded `InflightWindow` (``flush_inflight``), so COS connections
and node NICs carry many uploads at once and the virtual-time drain of N
dirty files approaches N / window instead of N.

Three policies ride on top of the pipeline:

* **dirty-page backpressure** — when a node's dirty bytes exceed
  ``dirty_hiwater_bytes``, its `rpc_stage_write` replies carry a stall hint
  that clients honour before issuing more foreground writes (client.py), and
  the flusher switches to priority eviction;
* **priority eviction** — above the watermark, candidates are ordered by
  `tiering.eviction_priority`: coldest-first (oldest mtime), largest-first,
  so each flushed inode frees the most cache for the longest time; below
  it, FIFO by inode id preserves the old behaviour.  The rule is shared
  with tier demotion so "what leaves the cache first" has one definition;
* **tier maintenance** — every tick ends by running ``maintain()`` on each
  registered storage backend that exposes one (the `TieredStore` capacity
  pass), so NVMe-tier watermark demotion rides the same cadence as dirty
  write-back (``tier_demotions`` counter).  The tiering invariants the
  flusher leans on (`core/tiering.py`): a tier-dirty key is copied to the
  durable base *before* its cache copy is dropped, demotion charges only
  the durable lane, and a persist that lands via the PutObject fast path
  may sit tier-dirty on NVMe — it is still crash-durable for Fig. 8
  purposes only after the tier demotes it, which `Cluster.scale_to_zero`
  forces via ``flush_cache()`` before the last node disappears.

The flusher is *driven* by `flush_interval_s` on the simclock: `poll()` runs
a tick only when the interval has elapsed, so callers can invoke it after
every foreground operation without over-flushing; `tick()` forces one pass;
`drain()` loops until no dirty state remains.  Everything it does is
observable through `counters` (inodes flushed, bytes uploaded, backpressure
stalls, priority picks), which `Cluster.dirty_counts()` and the benchmark
reports embed.
"""

from __future__ import annotations

from typing import TYPE_CHECKING

from .net import SimCrash, SimTimeout
from .simclock import InflightWindow
from .tiering import eviction_priority
from .types import FSError, InodeKind, ROOT_INODE, meta_key

if TYPE_CHECKING:  # pragma: no cover
    from .cluster import Cluster

_CLUSTER_CLIENT_ID = 0  # reserved transaction client id for the operator


class BackgroundFlusher:
    def __init__(self, cluster: "Cluster") -> None:
        self.cluster = cluster
        self.last_tick_t = 0.0
        self.counters: dict[str, float] = {
            "ticks": 0, "inodes_flushed": 0, "bytes_uploaded": 0,
            "backpressure_stalls": 0, "eviction_priority_picks": 0,
            "flush_errors": 0,
        }

    # =====================================================================
    # candidate selection
    # =====================================================================
    def _candidates(self) -> list[tuple[str, int, int, float]]:
        """Flushable dirty inodes as (coordinator_node, ino, size, mtime).
        Same eligibility rules as the serial path: the metadata owner
        coordinates, only COS-backed inodes flush, live directories persist
        only at zero scale."""
        cl = self.cluster
        out: list[tuple[str, int, int, float]] = []
        seen: set[int] = set()
        for s in list(cl.servers.values()):
            if not s.alive:
                continue
            for ino in list(s.metas.dirty_inos()):
                if ino in seen or ino == ROOT_INODE:
                    continue
                m = s.metas.get(ino)
                if m is None or s.owner(meta_key(ino)) != s.node_id:
                    continue
                if m.cos_bucket is None or m.cos_key is None:
                    continue
                if m.kind == InodeKind.DIR and not m.deleted:
                    continue
                seen.add(ino)
                out.append((s.node_id, ino, m.size, m.mtime))
        return out

    def dirty_bytes(self) -> int:
        return sum(s.state.dirty_bytes()
                   for s in self.cluster.servers.values() if s.alive)

    def under_pressure(self) -> bool:
        """True when any node exceeds its dirty high-watermark — the same
        per-node threshold `rpc_stage_write` uses for client stall hints."""
        hi = self.cluster.cfg.dirty_hiwater_bytes
        return hi > 0 and any(s.state.dirty_bytes() > hi
                              for s in self.cluster.servers.values()
                              if s.alive)

    # =====================================================================
    # pipelined flush pass
    # =====================================================================
    def tick(self, max_inodes: int | None = None) -> tuple[int, float]:
        """One pipelined flush pass; returns (flushed_count, t_end).

        All selected persists start from the current virtual time and run
        concurrently through the in-flight window; the pass completes at the
        latest persist's completion.  Foreground traffic issued meanwhile
        overlaps naturally on the shared resource lanes (Fig. 12)."""
        cl = self.cluster
        start = cl.clock.now
        self.counters["ticks"] += 1
        self.last_tick_t = start
        cands = self._candidates()
        pressured = self.under_pressure()
        if pressured:
            # priority eviction: coldest (oldest mtime) first, then largest
            # — the same rule tier demotion applies (tiering.py)
            cands.sort(key=lambda c: eviction_priority(c[3], c[2], c[1]))
        else:
            cands.sort(key=lambda c: c[1])
        if max_inodes is not None:
            cands = cands[:max_inodes]
        window = InflightWindow(cl.cfg.flush_inflight)
        ends: list[float] = []
        done = 0
        for node, ino, size, _mtime in cands:
            begin = window.admit(start)
            try:
                res, te = cl.router.rpc(None, node, "coord_persist", begin,
                                        ino=ino,
                                        client_id=_CLUSTER_CLIENT_ID,
                                        seq=cl._new_seq())
                if res.get("outcome") in ("commit", "deleted"):
                    done += 1
                    self.counters["inodes_flushed"] += 1
                    self.counters["bytes_uploaded"] += size
                    if pressured:
                        self.counters["eviction_priority_picks"] += 1
            except (SimTimeout, SimCrash, FSError):
                self.counters["flush_errors"] += 1
                te = cl.router.charge_timeout(begin)
            window.settle(te)
            ends.append(te)
        t = max(ends) if ends else start
        # tier maintenance rides the flush cadence: relieve fast-tier
        # capacity pressure (coldest-first demotion) after every pass
        for backend in cl.backends.values():
            if hasattr(backend, "maintain"):
                moved, tm = backend.maintain(t)
                if moved:
                    self.counters["tier_demotions"] = \
                        self.counters.get("tier_demotions", 0) + moved
                    t = max(t, tm)
        # server-side stall hints issued since the last aggregation
        self.counters["backpressure_stalls"] = sum(
            s.stats.get("bp_stalls", 0) for s in cl.servers.values())
        return done, t

    def poll(self) -> tuple[int, float]:
        """Interval-driven entry point: flush only when `flush_interval_s`
        has elapsed on the simclock (or immediately under backpressure)."""
        cl = self.cluster
        due = self.last_tick_t + cl.cfg.flush_interval_s
        if cl.clock.now < due and not self.under_pressure():
            return 0, cl.clock.now
        return self.tick()

    def drain(self, max_rounds: int = 8) -> int:
        """Flush until no eligible dirty inode remains; returns total."""
        cl = self.cluster
        total = 0
        for _ in range(max_rounds):
            n, t = self.tick()
            cl.clock.advance_to(t)
            total += n
            if n == 0:
                break
        return total

    def stats(self) -> dict[str, float]:
        out = dict(self.counters)
        out["dirty_bytes"] = self.dirty_bytes()
        return out
