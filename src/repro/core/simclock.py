"""Virtual time for the in-process cluster simulation.

Functional behaviour in `repro.core` is real (real bytes, real WAL files,
real replay); *time* is modeled.  Every hardware resource (a node's NVMe, a
node's NIC, the COS frontend) is a `Resource` with latency, bandwidth and a
bounded number of parallel lanes.  Operations are expressed as

    end = resource.acquire(start, nbytes)

where ``start`` is when the operation's inputs are ready.  Dataflow-parallel
operations (e.g. MPU part uploads from different chunk servers) simply take
``max`` over their completion times; serialization on a shared resource falls
out of the per-lane ``free_at`` bookkeeping.

The clock itself is only advanced by *synchronous* waits (an application call
returning), which is what lets asynchronous write-back overlap foreground
compute exactly as in the paper's Fig. 12.
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass, field


class SimClock:
    """Monotonic virtual clock shared by one simulated cluster."""

    def __init__(self) -> None:
        self.now: float = 0.0

    def advance_to(self, t: float) -> None:
        if t > self.now:
            self.now = t

    def sleep(self, dt: float) -> None:
        self.now += max(0.0, dt)

    def at(self, t: float) -> None:
        """Jump the clock to absolute time `t` — may rewind.  Open-loop load
        generation (`core/loadgen.py`) starts every operation at its
        *scheduled* arrival time regardless of when the previous one
        finished; `Resource` lanes keep their own ``free_at`` bookkeeping, so
        queueing delay under overload still accumulates correctly even
        though the foreground clock moves backwards between operations."""
        self.now = t


@dataclass
class Resource:
    """A serialized hardware resource with ``parallelism`` lanes.

    ``acquire(start, nbytes)`` books the earliest-free lane at
    ``max(start, lane_free)`` and occupies it for
    ``latency_s + nbytes / bandwidth_bps`` seconds.
    """

    name: str
    bandwidth_bps: float  # bytes/second
    latency_s: float = 0.0
    parallelism: int = 1
    _lanes: list[float] = field(default_factory=list)
    busy_time: float = 0.0  # total occupied seconds, for utilization reports

    def __post_init__(self) -> None:
        self._lanes = [0.0] * max(1, self.parallelism)
        heapq.heapify(self._lanes)

    def duration(self, nbytes: int) -> float:
        return self.latency_s + (nbytes / self.bandwidth_bps if nbytes else 0.0)

    def acquire(self, start: float, nbytes: int = 0) -> float:
        lane_free = heapq.heappop(self._lanes)
        begin = max(start, lane_free)
        dur = self.duration(nbytes)
        end = begin + dur
        self.busy_time += dur
        heapq.heappush(self._lanes, end)
        return end

    def reset(self) -> None:
        self._lanes = [0.0] * max(1, self.parallelism)
        heapq.heapify(self._lanes)
        self.busy_time = 0.0


class InflightWindow:
    """Bounded-concurrency admission window over virtual time.

    Models a client-side in-flight limit (outstanding RPCs, queued MPU part
    uploads, migration sends) that is *narrower* than the underlying hardware
    lanes: `admit(start)` returns the earliest time a new operation may begin
    given at most ``slots`` operations in flight, and the caller reports the
    operation's completion with ``settle(end)``.  Unlike `Resource`, the
    window adds no latency or bandwidth cost of its own — it only bounds
    overlap, so pipelined schedulers (persist parts, background flush,
    migration sends) stay tunable without distorting the hardware model.
    """

    def __init__(self, slots: int) -> None:
        self._slots = [0.0] * max(1, slots)
        heapq.heapify(self._slots)

    def admit(self, start: float) -> float:
        return max(start, heapq.heappop(self._slots))

    def settle(self, end: float) -> None:
        heapq.heappush(self._slots, end)


@dataclass
class HardwareModel:
    """Cost-model constants.  Defaults approximate the paper's two testbeds
    (§6: NVMe nodes with 100G NICs; COS regional bucket)."""

    # node-local persistent storage (NVMe)
    disk_write_bps: float = 2.0e9
    disk_read_bps: float = 3.0e9
    disk_latency_s: float = 30e-6
    disk_parallelism: int = 8
    # node NIC (100 Gb/s)
    nic_bps: float = 12.5e9
    net_rtt_s: float = 50e-6
    nic_parallelism: int = 8
    # loopback between colocated processes (detached deployment, same node)
    loopback_bps: float = 6.0e9
    loopback_rtt_s: float = 25e-6
    # node-local in-memory cache
    mem_bps: float = 12.0e9
    mem_latency_s: float = 1e-6
    # external COS (regional bucket): request latency + per-connection bw
    cos_latency_s: float = 30e-3
    cos_conn_bps: float = 120e6
    cos_parallelism: int = 64

    def make_disk(self, node: str) -> Resource:
        return Resource(f"disk:{node}", self.disk_write_bps, self.disk_latency_s,
                        self.disk_parallelism)

    def make_nic(self, node: str) -> Resource:
        return Resource(f"nic:{node}", self.nic_bps, 0.0, self.nic_parallelism)

    def make_cos(self) -> Resource:
        return Resource("cos", self.cos_conn_bps, self.cos_latency_s,
                        self.cos_parallelism)

    def make_lane(self, name: str, bps: float, latency_s: float,
                  parallelism: int) -> Resource:
        """Generic bandwidth lane for a pluggable storage backend
        (`cos.BackendProfile`): each backend owns one, so S3-like,
        GCS-like, and NVMe-tier traffic never contend with each other."""
        return Resource(name, bps, latency_s, parallelism)
