"""Per-server in-memory working state: inode metadata table and chunk store.

A chunk (§4.1) tracks its committed content as an ordered list of *segments*
(apply-in-order overwrites), each backed by a second-level-log `BulkRef` or a
COS fill; *outstanding writes* (§5.3) are staged per stage-id and promoted to
committed segments by a flush transaction.  All mutations happen through the
server's Raft state machine so replay reconstructs this exactly.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .raftlog import BulkRef, RaftLog
from .types import InodeMeta


@dataclass
class Segment:
    off: int          # offset within the chunk
    length: int
    ref: BulkRef | None   # bytes in the second-level log; None = zeros
                          # (truncate's zero-tail pseudo-segment, §5.4)

    def to_payload(self) -> dict:
        return {"off": self.off, "length": self.length,
                "ref": self.ref.to_payload() if self.ref else None}

    @staticmethod
    def from_payload(p: dict) -> "Segment":
        ref = BulkRef.from_payload(p["ref"]) if p.get("ref") else None
        return Segment(p["off"], p["length"], ref)


@dataclass
class StagedWrite:
    stage_id: str
    off: int
    length: int
    ref: BulkRef

    def to_payload(self) -> dict:
        return {"stage_id": self.stage_id, "off": self.off,
                "length": self.length, "ref": self.ref.to_payload()}

    @staticmethod
    def from_payload(p: dict) -> "StagedWrite":
        return StagedWrite(p["stage_id"], p["off"], p["length"],
                           BulkRef.from_payload(p["ref"]))


@dataclass
class ChunkState:
    ino: int
    chunk_off: int      # byte offset of this chunk within the file
    version: int = 0
    dirty: bool = False
    deleted: bool = False
    base_filled: list[Segment] = field(default_factory=list)   # clean COS fills
    segments: list[Segment] = field(default_factory=list)      # committed writes
    staged: dict[str, StagedWrite] = field(default_factory=dict)

    # ---- content assembly ----------------------------------------------------
    def covered(self, off: int, length: int) -> bool:
        """True if [off, off+length) is covered by fills/segments (no need to
        consult external storage)."""
        need = [(off, off + length)]
        for seg in self.base_filled + self.segments:
            need = _subtract(need, (seg.off, seg.off + seg.length))
            if not need:
                return True
        return not need

    def materialize(self, log: RaftLog, length: int) -> bytes:
        """Assemble the first `length` bytes of this chunk from fills then
        committed segments in commit order (later wins)."""
        buf = bytearray(length)
        for seg in self.base_filled + self.segments:
            if seg.off >= length:
                continue
            n = min(seg.length, length - seg.off)
            data = b"\0" * n if seg.ref is None else log.read_bulk(seg.ref)
            buf[seg.off:seg.off + n] = data[:n]
        return bytes(buf)

    def local_bytes(self) -> int:
        return sum(s.length for s in self.base_filled + self.segments
                   if s.ref is not None)

    def to_payload(self) -> dict:
        return {
            "ino": self.ino, "chunk_off": self.chunk_off,
            "version": self.version, "dirty": self.dirty,
            "deleted": self.deleted,
            "base_filled": [s.to_payload() for s in self.base_filled],
            "segments": [s.to_payload() for s in self.segments],
            "staged": {k: v.to_payload() for k, v in self.staged.items()},
        }

    @staticmethod
    def from_payload(p: dict) -> "ChunkState":
        return ChunkState(
            ino=p["ino"], chunk_off=p["chunk_off"], version=p["version"],
            dirty=p["dirty"], deleted=p["deleted"],
            base_filled=[Segment.from_payload(s) for s in p["base_filled"]],
            segments=[Segment.from_payload(s) for s in p["segments"]],
            staged={k: StagedWrite.from_payload(v)
                    for k, v in p.get("staged", {}).items()})


def _subtract(ranges: list[tuple[int, int]],
              cut: tuple[int, int]) -> list[tuple[int, int]]:
    out = []
    c0, c1 = cut
    for a, b in ranges:
        if c1 <= a or c0 >= b:
            out.append((a, b))
            continue
        if a < c0:
            out.append((a, c0))
        if c1 < b:
            out.append((c1, b))
    return out


class MetaTable:
    """Inode metadata owned by one server (a shard of the global namespace)."""

    def __init__(self) -> None:
        self.inodes: dict[int, InodeMeta] = {}

    def get(self, ino: int) -> InodeMeta | None:
        return self.inodes.get(ino)

    def put(self, meta: InodeMeta) -> None:
        self.inodes[meta.ino] = meta

    def evict(self, ino: int) -> None:
        self.inodes.pop(ino, None)

    def dirty_inos(self) -> list[int]:
        return [i for i, m in self.inodes.items() if m.dirty]


class ChunkTable:
    def __init__(self) -> None:
        self.chunks: dict[tuple[int, int], ChunkState] = {}

    def get(self, ino: int, chunk_off: int) -> ChunkState | None:
        return self.chunks.get((ino, chunk_off))

    def ensure(self, ino: int, chunk_off: int) -> ChunkState:
        key = (ino, chunk_off)
        if key not in self.chunks:
            self.chunks[key] = ChunkState(ino, chunk_off)
        return self.chunks[key]

    def evict(self, ino: int, chunk_off: int) -> None:
        self.chunks.pop((ino, chunk_off), None)

    def dirty_keys(self) -> list[tuple[int, int]]:
        return [k for k, c in self.chunks.items() if c.dirty]

    def for_ino(self, ino: int) -> list[ChunkState]:
        return [c for (i, _), c in self.chunks.items() if i == ino]
