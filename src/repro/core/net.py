"""RPC fabric for the in-process cluster.

Servers call each other through `Router.rpc(...)` — a direct Python call
wrapped with virtual-time accounting (destination NIC bandwidth + RTT, or the
loopback cost for a colocated client in the detached deployment, or zero for
the embedded deployment, §3.1).  Failure injection: dead destinations time
out; named injection points raise `SimCrash` inside server code to emulate
the black-dot crashes of Fig. 8.

Dispatch is *typed*: every remotely callable handler is registered with the
`@rpc_handler` decorator, which attaches an `RpcSpec` (wire name + declared
default payload sizes).  `Router.register` collects each server's handler
table once, and `Router.rpc` dispatches through it — an unregistered method
name is a programming error (`UnknownRpcError`), not a silent `getattr`.
The router also records per-method call counts, bytes, and virtual-time
latency, both globally (`Router.method_stats`) and into the destination
server's `stats` dict (`rpc.<method>.calls/bytes/vtime`).

Admission control (QoS) lives at this layer too, because the envelope is
the unit the fabric can police without understanding filesystem
semantics.  `Router.set_admission` installs a per-tenant GCRA token
bucket (`TenantQos`: ``rate_ops_s`` in *envelope* units — one fs-op is
several envelopes, ~4.7 for the mixed strict workload — plus ``burst``
and ``queue_depth``).  A conforming envelope passes untouched; an
over-rate one is *delayed* until its conforming time, up to
``queue_depth`` token intervals, beyond which it is *shed* as a typed
`AdmissionError` (EAGAIN, carrying ``retry_after_s``) without consuming
a token.  Untagged clients and the control-plane ``rpc_nodelist`` are
never policed.

The subtle part is *when* an envelope is charged.  An op queued behind a
backlog issues its trailing envelopes at post-queueing virtual times; if
the bucket charged those at dispatch time, the backlog itself would mint
refill credit (time passed → tokens accrued) and an overloaded tenant
would never shed.  Callers therefore pin each operation's charge time to
its open-loop *arrival* via `Router.note_arrival`, and `_admit` converts
the conforming-time wait into an incremental delay on top of whatever
straggle the envelope already carries.  Admission delays compose with
§5.2 dirty-page backpressure: the client diffs `Router.tenant_delay_s`
around staging and stalls only for the remainder of a ``bp_delay`` hint,
so the same virtual second is never charged twice.  Per-tenant
admitted/delayed/shed counters live in `Router.tenant_stats`.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable

from .simclock import HardwareModel, SimClock
from .types import AdmissionError, FSError

if TYPE_CHECKING:  # pragma: no cover
    from .server import CacheServer


class SimTimeout(Exception):
    """RPC to a dead/partitioned node; charged `timeout_s` of virtual time."""


class SimCrash(Exception):
    """A server crashed at an injected point mid-operation."""

    def __init__(self, node: str, point: str) -> None:
        super().__init__(f"{node} crashed at {point}")
        self.node = node
        self.point = point


class UnknownRpcError(Exception):
    """Dispatch to a method name that no `@rpc_handler` registered."""


@dataclass(frozen=True)
class RpcSpec:
    """Declared wire contract of one RPC handler."""

    name: str                 # wire name (defaults to the function name)
    request_bytes: int = 256  # default request payload size when the caller
    reply_bytes: int = 256    # ... does not pass nbytes_out / nbytes_in


def rpc_handler(name: str | None = None, *, request_bytes: int = 256,
                reply_bytes: int = 256) -> Callable:
    """Mark a server-subsystem method as a remotely callable RPC handler.

    The handler signature is `m(start: float, **kwargs) -> (result, end)`.
    Registration happens when the owning server is `Router.register`-ed.
    """
    def deco(fn: Callable) -> Callable:
        fn.__rpc_spec__ = RpcSpec(name or fn.__name__,  # type: ignore[attr-defined]
                                  request_bytes, reply_bytes)
        return fn
    return deco


def collect_handlers(*objs: Any) -> dict[str, tuple[Callable, RpcSpec]]:
    """Scan objects for `@rpc_handler`-decorated methods -> dispatch table."""
    table: dict[str, tuple[Callable, RpcSpec]] = {}
    for obj in objs:
        for attr in dir(type(obj)):
            fn = getattr(type(obj), attr, None)
            spec = getattr(fn, "__rpc_spec__", None)
            if spec is not None:
                if spec.name in table:  # pragma: no cover
                    raise AssertionError(f"duplicate RPC handler {spec.name}")
                table[spec.name] = (getattr(obj, attr), spec)
    return table


@dataclass(frozen=True)
class TenantQos:
    """Per-tenant admission parameters: a token bucket plus a bounded queue.

    ``rate_ops_s`` is the sustained admitted envelope rate (token refill);
    ``burst`` is the bucket capacity — envelopes admitted back-to-back after
    an idle period; ``queue_depth`` is how many envelopes' worth of backlog
    the fabric will *delay* rather than shed, so the maximum admission delay
    is ``queue_depth / rate_ops_s``.  One wire envelope costs one token
    (a batch counts once, same as `Router.rpc_count`)."""

    rate_ops_s: float
    burst: int = 8
    queue_depth: int = 32


class AdmissionControl:
    """Virtual-time token buckets (GCRA form), one per policed tenant.

    The bucket is kept as a theoretical-arrival-time (`tat`) per tenant:
    an envelope arriving at ``now`` owes ``wait = max(0, tat - tol - now)``
    where ``tol = (burst - 1) / rate`` is the idle credit.  ``wait`` within
    the bounded queue is served as an admission *delay* (the envelope
    dispatches late); beyond it the envelope is *shed* without consuming a
    token.  Exact at simclock boundaries: after a drained burst the next
    token is available precisely ``1 / rate`` later.  Tenants without a
    policy entry are unpoliced.

    The bucket must be driven by a per-tenant *monotone* clock — the time
    the tenant's operation **arrived** at the fabric, not the time each
    envelope happens to dispatch.  Envelope dispatch times include the
    queueing delay of earlier envelopes (and any admission delay the fabric
    itself added), so charging them would let an over-rate tenant mint
    refill credit from its own backlog and never accumulate debt.  Callers
    with naturally monotone send times (closed-loop clients) just pass
    those; the open-loop runner pins the charge time for all of an op's
    envelopes to the op's scheduled arrival via `Router.note_arrival`."""

    def __init__(self, policy: dict[str, TenantQos]) -> None:
        self.policy = dict(policy)
        self._tat: dict[str, float] = {}

    def decide(self, tenant: str, now: float) -> tuple[str, float]:
        """Returns ("admit", 0) | ("delay", wait) | ("shed", retry_after);
        `wait` is relative to `now`, the envelope's charge time."""
        qos = self.policy.get(tenant)
        if qos is None:
            return "admit", 0.0
        inc = 1.0 / qos.rate_ops_s
        tol = (max(1, qos.burst) - 1) * inc
        tat = max(self._tat.get(tenant, 0.0), now)
        wait = tat - tol - now
        # epsilon on both comparisons: tat accumulates `inc` per envelope,
        # so at an exact refill boundary (now == k / rate) float residue
        # would otherwise turn a conforming envelope into a spurious delay
        if wait <= 1e-12:
            self._tat[tenant] = tat + inc
            return "admit", 0.0
        if wait > qos.queue_depth * inc + 1e-12:
            return "shed", wait          # no token consumed, tat unchanged
        self._tat[tenant] = tat + inc
        return "delay", wait


class Router:
    def __init__(self, clock: SimClock, hw: HardwareModel,
                 timeout_s: float = 1.0) -> None:
        self.clock = clock
        self.hw = hw
        self.timeout_s = timeout_s
        self.servers: dict[str, "CacheServer"] = {}
        # node_id -> {method name -> (bound handler, spec)}
        self.handlers: dict[str, dict[str, tuple[Callable, RpcSpec]]] = {}
        self.partitioned: set[str] = set()
        # stats
        self.rpc_count = 0          # wire envelopes (a batch counts once)
        self.rpc_bytes = 0
        self.batch_envelopes = 0    # envelopes that carried > 1 sub-call
        self.batched_subcalls = 0   # sub-calls delivered inside batches
        # per-method: calls / bytes / vtime (summed reply latency) /
        # timeouts (unreachable dst) / errors (handler raised)
        self.method_stats: dict[str, dict[str, float]] = {}
        self._skeys: dict[str, tuple[str, str, str]] = {}
        # per-tenant QoS admission at the fabric edge (None = everything
        # admitted); tenant_stats: admitted / delayed / shed / delay_s
        self.admission: AdmissionControl | None = None
        self.tenant_stats: dict[str, dict[str, float]] = {}
        # open-loop arrival stamps: tenant -> charge time for its envelopes
        # (see AdmissionControl docstring); absent = charge at dispatch time
        self.tenant_clock: dict[str, float] = {}

    def register(self, server: "CacheServer") -> None:
        self.servers[server.node_id] = server
        self.handlers[server.node_id] = server.rpc_handlers()

    def unregister(self, node_id: str) -> None:
        self.servers.pop(node_id, None)
        self.handlers.pop(node_id, None)

    def reachable(self, node_id: str) -> bool:
        s = self.servers.get(node_id)
        return s is not None and s.alive and node_id not in self.partitioned

    def registered_methods(self, node_id: str) -> list[str]:
        return sorted(self.handlers.get(node_id, {}))

    # ---- timing ----------------------------------------------------------------
    def xfer(self, src: str | None, dst: str, nbytes: int, start: float,
             embedded_local: bool = False) -> float:
        """Time for a one-way transfer src->dst.  src None = external client."""
        if src == dst:
            if embedded_local:
                return start  # embedded deployment: same process, no hop
            # detached deployment, same node: loopback
            return start + self.hw.loopback_rtt_s / 2 + nbytes / self.hw.loopback_bps
        dst_srv = self.servers.get(dst)
        nic = dst_srv.nic if dst_srv is not None else None
        t = start + self.hw.net_rtt_s / 2
        if nic is not None:
            return nic.acquire(t, nbytes)
        return t + nbytes / self.hw.nic_bps

    # ---- per-tenant QoS admission ----------------------------------------------
    def set_admission(self, policy: dict[str, TenantQos] | None) -> None:
        """Install (or clear, with None/{}) per-tenant admission control.
        The policy applies to tenant-tagged envelopes only; untagged calls
        (server-to-server traffic, the operator, control-plane pulls) are
        never policed."""
        self.admission = AdmissionControl(policy) if policy else None

    def _tstat(self, tenant: str) -> dict[str, float]:
        st = self.tenant_stats.get(tenant)
        if st is None:
            st = {"admitted": 0, "delayed": 0, "shed": 0, "delay_s": 0.0}
            self.tenant_stats[tenant] = st
        return st

    def tenant_delay_s(self, tenant: str | None) -> float:
        """Cumulative admission delay charged to `tenant` so far.  Clients
        diff this around an operation to compose server backpressure hints
        with admission delays instead of double-counting the stall."""
        if tenant is None:
            return 0.0
        st = self.tenant_stats.get(tenant)
        return st["delay_s"] if st is not None else 0.0

    def note_arrival(self, tenant: str, t: float) -> None:
        """Pin the admission charge time for `tenant`'s next envelopes to
        `t` — an open-loop driver calls this with each op's scheduled
        arrival, so all of the op's envelopes are charged as one burst at
        arrival instead of at their (queueing-inflated) dispatch times."""
        self.tenant_clock[tenant] = t

    def _admit(self, tenant: str | None, method: str, start: float) -> float:
        """Apply admission control to one envelope; returns the (possibly
        delayed) dispatch time, or raises `AdmissionError` on shed."""
        if tenant is None or self.admission is None:
            return start
        charge = self.tenant_clock.get(tenant, start)
        verdict, wait = self.admission.decide(tenant, charge)
        st = self._tstat(tenant)
        if verdict == "shed":
            st["shed"] += 1
            raise AdmissionError(tenant, method, wait)
        st["admitted"] += 1
        if verdict == "delay":
            # the envelope may dispatch once its conforming time (relative
            # to the charge clock) has passed; service straggle that already
            # pushed `start` beyond it absorbs the admission delay for free
            extra = max(0.0, charge + wait - start)
            if extra > 0.0:
                st["delayed"] += 1
                st["delay_s"] += extra
            return start + extra
        return start

    def _mstat(self, method: str) -> dict[str, float]:
        st = self.method_stats.get(method)
        if st is None:
            st = {"calls": 0, "bytes": 0, "vtime": 0.0, "timeouts": 0,
                  "errors": 0}
            self.method_stats[method] = st
        return st

    def _stat_keys(self, method: str) -> tuple[str, str, str]:
        keys = self._skeys.get(method)
        if keys is None:
            keys = (f"rpc.{method}.calls", f"rpc.{method}.bytes",
                    f"rpc.{method}.vtime")
            self._skeys[method] = keys
        return keys

    def rpc(self, src: str | None, dst: str, method: str, start: float,
            nbytes_out: int | None = None, nbytes_in: int | None = None,
            nbytes_extra: int = 0, embedded_local: bool = False,
            tenant: str | None = None, **kwargs: Any) -> tuple[Any, float]:
        """Invoke registered handler `method` on server `dst`.

        The handler signature is `m(start: float, **kwargs) -> (result,
        end_time)`.  Returns the result and the time the reply lands back at
        the caller.  Payload sizes default to the handler's declared
        `RpcSpec` when not passed explicitly.  `nbytes_extra` declares
        payload bytes the handler moves on *other* resources on behalf of
        this call (e.g. a chunk-owner's MPU part upload straight to COS):
        they count toward the method's byte accounting so `rpc_stats()` is
        truthful about where the data goes, but are not charged to the
        src->dst NIC transfer, which only carries the control message.
        A `tenant` tag subjects the envelope to the installed admission
        policy: it may dispatch late (queued) or raise `AdmissionError`
        (shed) before any transfer is charged."""
        # a bad method name is a programming error even when the node is
        # down — surface it before (and without) any timeout accounting
        node_handlers = self.handlers.get(dst)
        if node_handlers is not None and method not in node_handlers:
            raise UnknownRpcError(
                f"no RPC handler {method!r} registered on {dst}; "
                f"known: {self.registered_methods(dst)}")
        start = self._admit(tenant, method, start)
        if not self.reachable(dst):
            self._mstat(method)["timeouts"] += 1
            raise SimTimeout(f"rpc {method} to {dst}: timeout "
                             f"(+{self.timeout_s}s at t={start:.6f})")
        fn, spec = node_handlers[method]
        n_out = spec.request_bytes if nbytes_out is None else nbytes_out
        n_in = spec.reply_bytes if nbytes_in is None else nbytes_in
        arrive = self.xfer(src, dst, n_out, start, embedded_local)
        server = self.servers[dst]
        try:
            result, end = fn(start=arrive, **kwargs)
        except BaseException:
            # failed dispatch (FSError / injected crash): keep the completed-
            # call counters consistent, account the failure separately
            self._mstat(method)["errors"] += 1
            raise
        back = self.xfer(dst, src, n_in, end, embedded_local) \
            if src is not None else self.xfer(dst, dst, n_in, end, True)
        latency = back - start
        # all call counters (legacy globals + per-method + per-server) count
        # *completed* dispatches; failures land in timeouts/errors above
        n_total = n_out + n_in + max(0, nbytes_extra)
        self.rpc_count += 1
        self.rpc_bytes += n_total
        mstat = self._mstat(method)
        mstat["calls"] += 1
        mstat["bytes"] += n_total
        mstat["vtime"] += latency
        k_calls, k_bytes, k_vtime = self._stat_keys(method)
        sstats = server.stats
        sstats[k_calls] = sstats.get(k_calls, 0) + 1
        sstats[k_bytes] = sstats.get(k_bytes, 0) + n_total
        sstats[k_vtime] = sstats.get(k_vtime, 0.0) + latency
        return result, back

    def rpc_batch(self, src: str | None, dst: str, calls: list[dict],
                  start: float, embedded_local: bool = False,
                  tenant: str | None = None
                  ) -> tuple[list[tuple[str, Any, float]], float]:
        """Same-destination coalescing: one wire envelope carrying N typed
        sub-calls.  Each element of `calls` is
        ``{"method": str, "kwargs": dict, "nbytes_out"?, "nbytes_in"?,
        "nbytes_extra"?}``.

        All sub-calls dispatch at the envelope's arrival time (server-side
        fan-out; shared hardware resources still serialize in virtual time
        through their lanes) and the reply lands after the *latest* sub-call
        completes.  Returns ``([("ok", result, end) | ("err", exc, end)],
        reply_time)`` — an `FSError` in one sub-call is reported in its slot
        without failing the others, exactly like N independent RPCs would
        behave.  Accounting: one envelope in `rpc_count`, but per-method
        calls/bytes/vtime are still credited per sub-call so `rpc_stats()`
        keeps full method visibility (plus a per-method `batched` counter)."""
        node_handlers = self.handlers.get(dst)
        if node_handlers is not None:
            for c in calls:
                if c["method"] not in node_handlers:
                    raise UnknownRpcError(
                        f"no RPC handler {c['method']!r} registered on {dst}; "
                        f"known: {self.registered_methods(dst)}")
        # one envelope = one token, same unit as rpc_count
        start = self._admit(tenant, f"batch[{len(calls)}]", start)
        if not self.reachable(dst):
            for c in calls:
                self._mstat(c["method"])["timeouts"] += 1
            raise SimTimeout(f"rpc_batch x{len(calls)} to {dst}: timeout "
                             f"(+{self.timeout_s}s at t={start:.6f})")
        sized = []
        for c in calls:
            fn, spec = node_handlers[c["method"]]
            n_out = c.get("nbytes_out")
            n_in = c.get("nbytes_in")
            sized.append((c, fn,
                          spec.request_bytes if n_out is None else n_out,
                          spec.reply_bytes if n_in is None else n_in))
        # one envelope: summed payloads + a small per-sub-call frame header
        total_out = sum(n for _, _, n, _ in sized) + 16 * len(sized)
        total_in = sum(n for _, _, _, n in sized) + 16 * len(sized)
        arrive = self.xfer(src, dst, total_out, start, embedded_local)
        server = self.servers[dst]
        results: list[tuple[str, Any, float]] = []
        ends = [arrive]
        for c, fn, n_out, n_in in sized:
            try:
                result, end = fn(start=arrive, **c["kwargs"])
                results.append(("ok", result, end))
                ends.append(end)
            except FSError as e:
                self._mstat(c["method"])["errors"] += 1
                results.append(("err", e, arrive))
        back = self.xfer(dst, src, total_in, max(ends), embedded_local) \
            if src is not None else self.xfer(dst, dst, total_in, max(ends),
                                              True)
        latency = back - start
        self.rpc_count += 1
        if len(calls) > 1:
            self.batch_envelopes += 1
            self.batched_subcalls += len(calls)
        sstats = server.stats
        for (c, fn, n_out, n_in), (status, _r, _e) in zip(sized, results):
            if status != "ok":
                continue
            n_total = n_out + n_in + max(0, c.get("nbytes_extra", 0))
            self.rpc_bytes += n_total
            mstat = self._mstat(c["method"])
            mstat["calls"] += 1
            mstat["bytes"] += n_total
            mstat["vtime"] += latency
            mstat["batched"] = mstat.get("batched", 0) + (len(calls) > 1)
            k_calls, k_bytes, k_vtime = self._stat_keys(c["method"])
            sstats[k_calls] = sstats.get(k_calls, 0) + 1
            sstats[k_bytes] = sstats.get(k_bytes, 0) + n_total
            sstats[k_vtime] = sstats.get(k_vtime, 0.0) + latency
        return results, back

    def charge_timeout(self, start: float) -> float:
        return start + self.timeout_s
