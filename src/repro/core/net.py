"""RPC fabric for the in-process cluster.

Servers call each other through `Router.rpc(...)` — a direct Python call
wrapped with virtual-time accounting (destination NIC bandwidth + RTT, or the
loopback cost for a colocated client in the detached deployment, or zero for
the embedded deployment, §3.1).  Failure injection: dead destinations time
out; named injection points raise `SimCrash` inside server code to emulate
the black-dot crashes of Fig. 8.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any, Callable

from .simclock import HardwareModel, SimClock

if TYPE_CHECKING:  # pragma: no cover
    from .server import CacheServer


class SimTimeout(Exception):
    """RPC to a dead/partitioned node; charged `timeout_s` of virtual time."""


class SimCrash(Exception):
    """A server crashed at an injected point mid-operation."""

    def __init__(self, node: str, point: str) -> None:
        super().__init__(f"{node} crashed at {point}")
        self.node = node
        self.point = point


class Router:
    def __init__(self, clock: SimClock, hw: HardwareModel,
                 timeout_s: float = 1.0) -> None:
        self.clock = clock
        self.hw = hw
        self.timeout_s = timeout_s
        self.servers: dict[str, "CacheServer"] = {}
        self.partitioned: set[str] = set()
        # stats
        self.rpc_count = 0
        self.rpc_bytes = 0

    def register(self, server: "CacheServer") -> None:
        self.servers[server.node_id] = server

    def unregister(self, node_id: str) -> None:
        self.servers.pop(node_id, None)

    def reachable(self, node_id: str) -> bool:
        s = self.servers.get(node_id)
        return s is not None and s.alive and node_id not in self.partitioned

    # ---- timing ----------------------------------------------------------------
    def xfer(self, src: str | None, dst: str, nbytes: int, start: float,
             embedded_local: bool = False) -> float:
        """Time for a one-way transfer src->dst.  src None = external client."""
        if src == dst:
            if embedded_local:
                return start  # embedded deployment: same process, no hop
            # detached deployment, same node: loopback
            return start + self.hw.loopback_rtt_s / 2 + nbytes / self.hw.loopback_bps
        dst_srv = self.servers.get(dst)
        nic = dst_srv.nic if dst_srv is not None else None
        t = start + self.hw.net_rtt_s / 2
        if nic is not None:
            return nic.acquire(t, nbytes)
        return t + nbytes / self.hw.nic_bps

    def rpc(self, src: str | None, dst: str, method: str, start: float,
            nbytes_out: int = 256, nbytes_in: int = 256,
            embedded_local: bool = False, **kwargs: Any) -> tuple[Any, float]:
        """Invoke `method` on server `dst`.  The server method signature is
        `m(start: float, **kwargs) -> (result, end_time)`.  Returns the result
        and the time the reply lands back at the caller."""
        self.rpc_count += 1
        self.rpc_bytes += nbytes_out + nbytes_in
        if not self.reachable(dst):
            raise SimTimeout(f"rpc {method} to {dst}: timeout "
                             f"(+{self.timeout_s}s at t={start:.6f})")
        arrive = self.xfer(src, dst, nbytes_out, start, embedded_local)
        server = self.servers[dst]
        fn: Callable = getattr(server, method)
        result, end = fn(start=arrive, **kwargs)
        back = self.xfer(dst, src, nbytes_in, end, embedded_local) \
            if src is not None else self.xfer(dst, dst, nbytes_in, end, True)
        return result, back

    def charge_timeout(self, start: float) -> float:
        return start + self.timeout_s
