"""2PC coordinator role: plan building and drive (§4.4).

`Coordinator` turns each file operation into a multi-node plan
(`{node_id: {"cmd": Cmd, "ops": [...], "keys": [...]}}`) and drives the 2PC
over the router — or takes the single-node fast path, which commutes to one
local log append ("we do not use this protocol for updates at a single
node", §4.4).  Durable TX_COORD_BEGIN/DECIDE records let a crashed
coordinator resume committing or aborting after replay (`recover_pending`).
The client sends each file operation to "the node for metadata as a
transaction coordinator" (§4.4), so every `coord_*` handler first checks
this server owns the primary metadata key.
"""

from __future__ import annotations

from .net import SimCrash, SimTimeout, rpc_handler
from .participant import Participant
from .state import ServerState
from .txn import txid_from_payload, txid_payload
from .types import (Cmd, Errno, FSError, InodeKind, InodeMeta, TxId,
                    chunk_key, meta_key)


def _abort_error(what: str, res: dict) -> FSError:
    """ECONFLICT carrying the wait-die verdict ("queued" keeps its place in
    line; the client's backoff reads it to retry sooner)."""
    e = FSError(Errno.ECONFLICT,
                f"{what} aborted ({res.get('why', 'conflict')})")
    e.why = res.get("why")
    return e


class Coordinator:
    def __init__(self, state: ServerState, wal: Participant) -> None:
        self.state = state
        self.wal = wal

    # =====================================================================
    # generic 2PC drive
    # =====================================================================
    def _dispatch_2pc(self, node: str, method: str, start: float,
                      nbytes_out: int | None = None, **kw
                      ) -> tuple[dict, float]:
        """One 2PC message.  The coordinator's own participant runs in the
        same process, so messages to self dispatch in-process — no loopback
        envelope, no NIC time — which alone removes two wire messages from
        every transaction whose coordinator is also a participant (it almost
        always is: the coordinator owns the primary metadata key)."""
        st = self.state
        if node == st.node_id:
            st.bump("tx_self_dispatch")
            return getattr(self.wal, method)(start, **kw)
        return st.router.rpc(st.node_id, node, method, start,
                             nbytes_out=nbytes_out, **kw)

    def coord_execute(self, start: float, client_id: int, seq: int,
                      plan: dict[str, dict]) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        done = st.coord_done.get((client_id, seq))
        if done is not None and done[1] == "commit":
            # duplicated request (§4.5): replay the committed outcome.  An
            # *aborted* (client_id, seq) falls through and re-executes with a
            # fresh txseq — the retry of a conflicted operation must be able
            # to claim the wait-die reservation its earlier attempt earned.
            return {"outcome": done[1], "dup": True}, start
        # single-node fast path: everything on this server -> one log append
        if set(plan) == {st.node_id}:
            ent = plan[st.node_id]
            txid = TxId(client_id, seq, 0)
            verdict = st.locks.acquire(
                list(ent["keys"]), txid, now=start,
                wait_die=st.cfg.lock_mode == "waitdie")
            if verdict != "granted":
                st.bump("lock_conflict")
                st.bump(f"lock_{verdict}")
                e = FSError(Errno.ECONFLICT,
                            f"local lock conflict ({verdict})")
                e.why = verdict
                raise e
            try:
                st.check_writable()
                t = self.wal.log(Cmd.LOCAL_META_UPDATE,
                                 {"ops": ent["ops"]}, start)
            finally:
                st.locks.release(txid, now=start)
            st.bump("tx_local")
            return {"outcome": "commit"}, t

        txid = TxId(client_id, seq, st.txseq)
        txid_p = txid_payload(txid)
        t = self.wal.log(Cmd.TX_COORD_BEGIN,
                         {"txid": txid_p, "nodes": sorted(plan)}, start)
        st.crash_at("coord_after_begin")
        votes_ok, ends, why = True, [], None
        for node in sorted(plan):
            ent = plan[node]
            try:
                res, te = self._dispatch_2pc(
                    node, "rpc_prepare", t,
                    nbytes_out=sum(len(str(o)) for o in ent["ops"]) + 128,
                    txid_p=txid_p, cmd_id=int(ent["cmd"]), ops=ent["ops"],
                    keys=ent["keys"], nl_version=None)
                ends.append(te)
                if not res["vote"]:
                    votes_ok = False
                    why = why or res.get("why")
            except (SimTimeout, SimCrash):
                ends.append(st.router.charge_timeout(t))
                votes_ok = False
        t = max(ends) if ends else t
        decide = Cmd.TX_COORD_DECIDE_COMMIT if votes_ok \
            else Cmd.TX_COORD_DECIDE_ABORT
        t = self.wal.log(decide, {"txseq": txid.txseq, "client_id": client_id,
                                  "seq": seq}, t)
        st.crash_at("coord_after_decide")
        t = self.send_decision(txid, sorted(plan), commit=votes_ok, start=t)
        st.coord_pending.pop(txid.txseq, None)
        st.bump("tx_commit" if votes_ok else "tx_abort")
        out = {"outcome": "commit" if votes_ok else "abort"}
        if why is not None:
            out["why"] = why    # wait-die verdict, surfaced to client backoff
        return out, t

    def send_decision(self, txid: TxId, nodes: list[str], commit: bool,
                      start: float) -> float:
        st = self.state
        txid_p = txid_payload(txid)
        method = "rpc_commit" if commit else "rpc_abort"
        ends = []
        for node in nodes:
            try:
                _, te = self._dispatch_2pc(node, method, start, txid_p=txid_p)
                ends.append(te)
            except (SimTimeout, SimCrash):
                # participant will learn the outcome on recovery / retry
                ends.append(st.router.charge_timeout(start))
        return max(ends) if ends else start

    def recover_pending(self, start: float) -> float:
        """Re-drive in-doubt coordinator transactions after replay (§4.4).
        Decisions for different transactions bound for the same participant
        coalesce into one batched envelope per node."""
        st = self.state
        t = start
        by_node: dict[str, list[dict]] = {}
        local: list[tuple[str, dict]] = []
        for txseq, info in sorted(st.coord_pending.items()):
            txid = txid_from_payload(info["txid"])
            # undecided or decided-abort: abort is always safe pre-commit
            method = "rpc_commit" if info["decided"] == "commit" \
                else "rpc_abort"
            for node in info["nodes"]:
                call = {"method": method,
                        "kwargs": {"txid_p": txid_payload(txid)}}
                if node == st.node_id:
                    local.append((method, call["kwargs"]))
                else:
                    by_node.setdefault(node, []).append(call)
        ends = [t]
        for method, kw in local:
            _, te = getattr(self.wal, method)(t, **kw)
            ends.append(te)
        for node, calls in sorted(by_node.items()):
            try:
                if st.cfg.batch_rpcs:
                    _, te = st.router.rpc_batch(st.node_id, node, calls, t)
                    ends.append(te)
                else:
                    for c in calls:
                        _, te = st.router.rpc(st.node_id, node, c["method"],
                                              t, **c["kwargs"])
                        ends.append(te)
            except (SimTimeout, SimCrash):
                ends.append(st.router.charge_timeout(t))
        st.coord_pending.clear()
        return max(ends)

    # =====================================================================
    # plan building helpers
    # =====================================================================
    def _plan_add(self, plan: dict, node: str, op: dict, keys: list[str],
                  cmd: Cmd = Cmd.TX_PREPARE_META) -> None:
        ent = plan.setdefault(node, {"cmd": cmd, "ops": [], "keys": []})
        ent["ops"].append(op)
        for k in keys:
            if k not in ent["keys"]:
                ent["keys"].append(k)

    def _require_owner(self, key: str) -> None:
        if self.state.owner(key) != self.state.node_id:
            raise FSError(Errno.ESTALE,
                          f"{self.state.node_id} is not the owner of {key}")

    # =====================================================================
    # FS-operation coordinators
    # =====================================================================
    @rpc_handler()
    def coord_create(self, start: float, client_id: int, seq: int, parent: int,
                     name: str, kind: int, cos_bucket: str | None,
                     cos_key: str | None, mtime: float,
                     nl_version: int | None = None) -> tuple[dict, float]:
        """Create a file/dir: new metadata on its owner + parent dir link.
        Coordinator = parent directory owner (it allocates the inode)."""
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        self._require_owner(meta_key(parent))
        d = st.metas.get(parent)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"parent {parent}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"parent {parent}")
        if name in d.children:
            raise FSError(Errno.EEXIST, f"{parent}/{name}")
        ino = st.alloc_ino()
        meta = InodeMeta(ino=ino, kind=InodeKind(kind), size=0, mtime=mtime,
                         dirty=True, cos_bucket=cos_bucket, cos_key=cos_key,
                         loaded=True)
        plan: dict[str, dict] = {}
        self._plan_add(plan, st.owner(meta_key(ino)),
                       {"kind": "meta_put", "meta": meta.to_payload()},
                       [meta_key(ino)])
        self._plan_add(plan, st.node_id,
                       {"kind": "dir_link", "ino": parent, "name": name,
                        "child": ino, "mtime": mtime},
                       [meta_key(parent)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise _abort_error("create", res)
        return {"ino": ino}, t

    @rpc_handler()
    def coord_load_dir(self, start: float, client_id: int, seq: int, ino: int,
                       nl_version: int | None = None) -> tuple[dict, float]:
        """§3.2: materialize a directory's children from the COS listing.
        Load-once; clean child metas are created on their owner nodes."""
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        self._require_owner(meta_key(ino))
        d = st.metas.get(ino)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"ino {ino}")
        if d.loaded or d.cos_bucket is None:
            return {"children": dict(d.children)}, start
        prefix = d.cos_key or ""
        objs, prefixes, t = st.backend_for(d.cos_bucket).list_prefix(
            d.cos_bucket, prefix, start=start)
        plan: dict[str, dict] = {}
        new_children: dict[str, int] = {}
        for key, size in objs:
            nm = key[len(prefix):]
            if not nm or nm in d.children:
                continue
            cino = st.alloc_ino()
            meta = InodeMeta(ino=cino, kind=InodeKind.FILE, size=size,
                             dirty=False, cos_bucket=d.cos_bucket, cos_key=key,
                             loaded=True)
            new_children[nm] = cino
            self._plan_add(plan, st.owner(meta_key(cino)),
                           {"kind": "meta_put", "meta": meta.to_payload()},
                           [meta_key(cino)])
        for pfx in prefixes:
            nm = pfx[len(prefix):].rstrip("/")
            if not nm or nm in d.children:
                continue
            cino = st.alloc_ino()
            meta = InodeMeta(ino=cino, kind=InodeKind.DIR, dirty=False,
                             cos_bucket=d.cos_bucket, cos_key=pfx,
                             loaded=False)
            new_children[nm] = cino
            self._plan_add(plan, st.owner(meta_key(cino)),
                           {"kind": "meta_put", "meta": meta.to_payload()},
                           [meta_key(cino)])
        self._plan_add(plan, st.node_id,
                       {"kind": "dir_set_children", "ino": ino,
                        "children": new_children, "loaded": True},
                       [meta_key(ino)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(t, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise _abort_error("load_dir", res)
        d = st.metas.get(ino)
        st.bump("dir_loads")
        return {"children": dict(d.children) if d else {}}, t

    @rpc_handler(request_bytes=512)
    def coord_flush_write(self, start: float, client_id: int, seq: int,
                          ino: int, staged: list, new_size: int, mtime: float,
                          nl_version: int | None = None) -> tuple[dict, float]:
        """§5.3: the flush transaction — promote staged chunk writes and
        update metadata size atomically.  staged = [[chunk_off, [stage_ids]]]."""
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = st.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        plan: dict[str, dict] = {}
        for chunk_off, stage_ids in staged:
            self._plan_add(plan, st.owner(chunk_key(ino, chunk_off)),
                           {"kind": "chunk_promote", "ino": ino,
                            "chunk_off": chunk_off, "stage_ids": stage_ids},
                           [chunk_key(ino, chunk_off)], Cmd.TX_PREPARE_CHUNK)
        self._plan_add(plan, st.node_id,
                       {"kind": "meta_set", "ino": ino,
                        "size": max(new_size, 0), "mtime": mtime,
                        "dirty": True},
                       [meta_key(ino)])
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise _abort_error("flush", res)
        return {"size": new_size}, t

    @rpc_handler()
    def coord_unlink(self, start: float, client_id: int, seq: int, parent: int,
                     name: str, ino: int, nl_version: int | None = None
                     ) -> tuple[dict, float]:
        """§5.4: set deleted+dirty on metadata and chunks + unlink from parent;
        the COS delete happens at the next persisting transaction."""
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = st.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if m.kind == InodeKind.DIR and m.children:
            raise FSError(Errno.ENOTEMPTY, f"ino {ino}")
        plan: dict[str, dict] = {}
        self._plan_add(plan, st.node_id,
                       {"kind": "meta_set", "ino": ino, "deleted": True,
                        "dirty": True, "mtime": start},
                       [meta_key(ino)])
        for coff in st.chunk_offsets(m.size):
            self._plan_add(plan, st.owner(chunk_key(ino, coff)),
                           {"kind": "chunk_delete", "ino": ino,
                            "chunk_off": coff},
                           [chunk_key(ino, coff)], Cmd.TX_PREPARE_CHUNK)
        self._plan_add(plan, st.owner(meta_key(parent)),
                       {"kind": "dir_unlink", "ino": parent, "name": name},
                       [meta_key(parent)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise _abort_error("unlink", res)
        return {"ok": True}, t

    @rpc_handler()
    def coord_rename(self, start: float, client_id: int, seq: int,
                     src_parent: int, src_name: str, dst_parent: int,
                     dst_name: str, ino: int, new_cos_key: str | None,
                     nl_version: int | None = None) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = st.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if m.kind == InodeKind.DIR:
            # directory rename would need a recursive COS re-key; like other
            # COS wrapper FSs we reject it (documented in DESIGN.md)
            raise FSError(Errno.EINVAL, "directory rename unsupported")
        plan: dict[str, dict] = {}
        op = {"kind": "meta_set", "ino": ino, "dirty": True,
              "cos_key": new_cos_key}
        if m.cos_key:
            op["add_old_key"] = m.cos_key
        self._plan_add(plan, st.node_id, op, [meta_key(ino)])
        self._plan_add(plan, st.owner(meta_key(src_parent)),
                       {"kind": "dir_unlink", "ino": src_parent,
                        "name": src_name},
                       [meta_key(src_parent)], Cmd.TX_PREPARE_DIR)
        self._plan_add(plan, st.owner(meta_key(dst_parent)),
                       {"kind": "dir_link", "ino": dst_parent,
                        "name": dst_name, "child": ino},
                       [meta_key(dst_parent)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise _abort_error("rename", res)
        return {"ok": True}, t

    @rpc_handler()
    def coord_truncate(self, start: float, client_id: int, seq: int, ino: int,
                       new_size: int, mtime: float,
                       nl_version: int | None = None) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = st.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        plan: dict[str, dict] = {}
        self._plan_add(plan, st.node_id,
                       {"kind": "meta_set", "ino": ino, "size": new_size,
                        "mtime": mtime, "dirty": True}, [meta_key(ino)])
        # chunks entirely beyond the new size are deleted; the boundary
        # chunk gets a zero-tail so re-growing never exposes stale bytes
        for coff in st.chunk_offsets(m.size):
            if coff >= new_size:
                self._plan_add(plan, st.owner(chunk_key(ino, coff)),
                               {"kind": "chunk_delete", "ino": ino,
                                "chunk_off": coff},
                               [chunk_key(ino, coff)], Cmd.TX_PREPARE_CHUNK)
            elif coff + st.cfg.chunk_size > new_size:
                frm = new_size - coff
                self._plan_add(plan, st.owner(chunk_key(ino, coff)),
                               {"kind": "chunk_zero_tail", "ino": ino,
                                "chunk_off": coff, "from": frm,
                                "length": st.cfg.chunk_size - frm},
                               [chunk_key(ino, coff)], Cmd.TX_PREPARE_CHUNK)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise _abort_error("truncate", res)
        return {"ok": True}, t
