"""Capacity-aware storage tiering — promotion/demotion over multi-backend
stacks (the Hoard-style cache tier over cloud storage).

`TieredStore` binds an ordered stack of `ObjectBackend`s — fastest first
(e.g. a bounded `NvmeStore`), a durable unbounded backend last (S3- or
GCS-like) — behind the exact same put/get/head/exists/list/delete/MPU
surface `CosStore` exposes, so `persist.py`, the server read path, and the
benchmarks route through a tier stack without knowing it is one.  The
policy knobs live in `TierPolicy`; the demotion engine is `maintain()`,
driven by the background flusher's tick (`core/flusher.py`) so capacity
pressure is relieved on the same cadence as dirty write-back.

Contracts the stack guarantees (asserted by `tests/test_tiering.py`):

* **Dirty durability before eviction.**  A write-back put lands on the
  fastest tier with room and the key is marked *tier-dirty* (newest copy
  not on a durable tier).  A tier-dirty key is never evicted: making room
  or demoting always *copies it to the durable tier first* (charging the
  durable lane), then drops the cache copy.  `CosCapacityError` from the
  fast tier therefore never loses data — worst case the put falls through
  to the durable tier directly.  MPU traffic goes straight to the durable
  tier (parts are bulk uploads), and a committed MPU invalidates any stale
  cache copy of its key.
* **Capacity accounting is the backend's.**  The stack never shadows
  `used_bytes`; it reacts to the fast tier's own `capacity_bytes` (via
  `CosCapacityError` and the `demote_hiwater`/`demote_lowater` watermarks),
  so the backend's accounting and the policy can never disagree.
* **Lane charging stays per-tier.**  Every byte moved charges exactly the
  lanes it crosses: a cache hit charges only the fast tier, a miss only
  the durable tier, a demotion charges the durable put, and a promotion's
  cache fill is charged on the fast lane *asynchronously* (the read
  returns at the durable read's end; the fill occupies fast-tier lanes
  afterwards, like any background write-back).
* **Eviction order reuses the flusher's priority machinery.**  Demotion
  candidates are ordered by `eviction_priority` — coldest-first (oldest
  last access), then largest-first — the same rule
  `BackgroundFlusher.tick` applies under dirty-page pressure, so "which
  data leaves the expensive tier first" has one definition repo-wide.
* **Determinism.**  Heat counters, residency, and the demotion order are
  plain dicts keyed by (bucket, key) with sorted tie-breaks; the same op
  sequence against the same stack yields identical virtual end times.

A single-backend "stack" is just the backend itself: binding a bucket to
one `CosStore` (or leaving the default binding) bypasses this module
entirely and reproduces the pre-tiering fingerprints bit-for-bit — the
metamorphic equivalence test pins that.
"""

from __future__ import annotations

from dataclasses import dataclass

from .cos import CosCapacityError, CosError, ObjectBackend
from .simclock import SimClock


def eviction_priority(last_touch: float, size: int, tiebreak) -> tuple:
    """Shared eviction ordering: coldest first (oldest last touch), then
    largest first, then a deterministic tiebreak.  Used by the background
    flusher's under-pressure candidate sort and by tier demotion — one
    definition of "what leaves the cache first" for the whole repo."""
    return (last_touch, -size, tiebreak)


@dataclass
class TierPolicy:
    """Knobs of the promotion/demotion engine.

    * ``promote_min_hits`` — reads of a key served by a lower tier before
      it is promoted into the fast tier (1 = promote on first access);
    * ``demote_hiwater`` / ``demote_lowater`` — fractions of the fast
      tier's capacity: `maintain()` starts demoting above hiwater and
      stops once usage falls to lowater (mirrors the flusher's dirty-page
      watermarks);
    * ``writeback`` — puts land on the fast tier (tier-dirty until
      demoted); False = write-through to the durable tier only.
    """

    promote_min_hits: int = 2
    demote_hiwater: float = 0.90
    demote_lowater: float = 0.70
    writeback: bool = True


class TieredStore:
    """An ordered backend stack behind the single-store API.

    ``tiers[0]`` is the fast (bounded) tier, ``tiers[-1]`` must be durable
    and unbounded — it is the demotion target and the MPU endpoint.  Two
    tiers are the supported configuration (fast cache + durable base);
    middle tiers are read-preferred but never demotion targets.
    """

    def __init__(self, tiers: list[ObjectBackend], clock: SimClock,
                 policy: TierPolicy | None = None,
                 name: str = "tiered") -> None:
        assert len(tiers) >= 2, "a tier stack needs a cache and a base"
        assert tiers[-1].durable, "the last tier must be durable"
        assert tiers[-1].profile.capacity_bytes is None, \
            "the durable base tier must be unbounded"
        self.tiers = tiers
        self.clock = clock
        self.policy = policy or TierPolicy()
        self.name = name
        self.durable = True  # the *stack* is durable (via its base tier)
        # (bucket, key) -> [hits, last_touch]: read heat for promotion and
        # the coldest-first demotion order
        self._heat: dict[tuple[str, str], list] = {}
        # keys whose newest copy lives only on a non-durable tier
        self._tier_dirty: set[tuple[str, str]] = set()
        self.counters: dict[str, float] = {
            "fast_hits": 0, "base_reads": 0, "promotions": 0,
            "demotions": 0, "evictions": 0, "writeback_puts": 0,
            "writethrough_puts": 0, "room_demotions": 0,
        }

    # ---- residency helpers ------------------------------------------------
    @property
    def fast(self) -> ObjectBackend:
        return self.tiers[0]

    @property
    def base(self) -> ObjectBackend:
        return self.tiers[-1]

    def tier_of(self, bucket: str, key: str) -> ObjectBackend | None:
        for t in self.tiers:
            if t.exists(bucket, key):
                return t
        return None

    def _touch(self, bucket: str, key: str, t: float) -> int:
        h = self._heat.setdefault((bucket, key), [0, t])
        h[0] += 1
        h[1] = max(h[1], t)
        return h[0]

    def _forget(self, bucket: str, key: str) -> None:
        self._heat.pop((bucket, key), None)
        self._tier_dirty.discard((bucket, key))

    # ---- dirty-durability + capacity machinery ---------------------------
    def _demote(self, bucket: str, key: str, start: float) -> float:
        """Copy a fast-tier key down to the durable base (if tier-dirty),
        then drop the cache copy.  The durable put charges the base lane;
        the cache drop is a metadata-only eviction."""
        data = self.fast._objects.get((bucket, key))
        if data is None:
            return start
        t = start
        if (bucket, key) in self._tier_dirty:
            t = self.base.put_object(bucket, key, data, start=t)
            self._tier_dirty.discard((bucket, key))
            self.counters["demotions"] += 1
        else:
            self.counters["evictions"] += 1
        if hasattr(self.fast, "evict"):
            self.fast.evict(bucket, key)
        else:  # pragma: no cover - cache tiers are NvmeStore in practice
            self.fast._objects.pop((bucket, key), None)
        return t

    def _fast_residents(self) -> list[tuple[tuple, int]]:
        """Fast-tier residency as ((bucket, key), size), eviction-ordered:
        coldest first, then largest — the flusher's priority rule."""
        rows = [((b, k), len(v)) for (b, k), v in self.fast._objects.items()]
        rows.sort(key=lambda r: eviction_priority(
            self._heat.get(r[0], [0, 0.0])[1], r[1], r[0]))
        return rows

    def _make_room(self, nbytes: int, start: float) -> tuple[bool, float]:
        """Demote/evict coldest-first until `nbytes` fit in the fast tier.
        Dirty keys are demoted (durable put charged), clean ones evicted
        free.  Returns (room_made, t)."""
        free = self.fast.free_bytes()
        if free is None or free >= nbytes:
            return True, start
        cap = self.fast.profile.capacity_bytes
        if cap is not None and nbytes > cap:
            return False, start  # larger than the whole tier
        t = start
        for (bucket, key), _size in self._fast_residents():
            if (self.fast.free_bytes() or 0) >= nbytes:
                break
            was_dirty = (bucket, key) in self._tier_dirty
            t = self._demote(bucket, key, t)
            if was_dirty:
                self.counters["room_demotions"] += 1
        return (self.fast.free_bytes() or 0) >= nbytes, t

    def under_pressure(self) -> bool:
        cap = self.fast.profile.capacity_bytes
        return cap is not None and \
            self.fast.used_bytes() > self.policy.demote_hiwater * cap

    def maintain(self, start: float) -> tuple[int, float]:
        """Capacity-pressure pass, driven by the flusher's tick: when the
        fast tier sits above `demote_hiwater`, demote/evict coldest-first
        down to `demote_lowater`.  Returns (keys_moved, t_end)."""
        cap = self.fast.profile.capacity_bytes
        if cap is None or not self.under_pressure():
            return 0, start
        target = self.policy.demote_lowater * cap
        t = start
        moved = 0
        for (bucket, key), _size in self._fast_residents():
            if self.fast.used_bytes() <= target:
                break
            t = self._demote(bucket, key, t)
            moved += 1
        return moved, t

    def flush_cache(self, start: float) -> float:
        """Demote every fast-tier resident (used by scale-to-zero and the
        cold-read benchmarks): afterwards the durable base holds all data
        and the fast tier is empty."""
        t = start
        for (bucket, key), _size in self._fast_residents():
            t = self._demote(bucket, key, t)
        return t

    # ---- data plane (the CosStore surface) -------------------------------
    def put_object(self, bucket: str, key: str, data: bytes,
                   start: float | None = None) -> float:
        t0 = self.clock.now if start is None else start
        if self.policy.writeback:
            ok, t0 = self._make_room(len(data), t0)
            if ok:
                try:
                    end = self.fast.put_object(bucket, key, data, start=t0)
                except CosCapacityError:  # raced accounting; fall through
                    ok = False
                else:
                    self._tier_dirty.add((bucket, key))
                    self._heat.setdefault((bucket, key), [0, end])[1] = end
                    self.counters["writeback_puts"] += 1
                    # a stale base copy stays masked by fastest-first reads
                    return end
        # write-through (policy, or object larger than the cache tier)
        end = self.base.put_object(bucket, key, data, start=t0)
        self._tier_dirty.discard((bucket, key))
        if hasattr(self.fast, "evict"):
            self.fast.evict(bucket, key)  # never serve a stale cache copy
        self.counters["writethrough_puts"] += 1
        return end

    def get_object(self, bucket: str, key: str,
                   rng: tuple[int, int] | None = None,
                   start: float | None = None) -> tuple[bytes, float]:
        t0 = self.clock.now if start is None else start
        tier = self.tier_of(bucket, key)
        if tier is None:
            raise CosError(f"NoSuchKey: {self.name}://{bucket}/{key}")
        data, end = tier.get_object(bucket, key, rng=rng, start=t0)
        hits = self._touch(bucket, key, end)
        if tier is self.fast:
            self.counters["fast_hits"] += 1
            return data, end
        self.counters["base_reads"] += 1
        if hits >= self.policy.promote_min_hits:
            self._promote(bucket, key, end)
        return data, end

    def _promote(self, bucket: str, key: str, t: float) -> None:
        """Fill the fast tier with a hot lower-tier object.  The fill is
        asynchronous: it charges the fast lane starting at the read's end
        but never extends the read itself.  Room is made by evicting clean
        cold keys only — promotion must not force dirty demotions."""
        full = self.base._objects.get((bucket, key))
        if full is None or self.fast.exists(bucket, key):
            return
        free = self.fast.free_bytes()
        if free is not None and free < len(full):
            # clean-only room: evict cold clean residents, skip dirty ones
            need = len(full)
            for (b2, k2), _size in self._fast_residents():
                if (self.fast.free_bytes() or 0) >= need:
                    break
                if (b2, k2) in self._tier_dirty:
                    continue
                self._demote(b2, k2, t)
            if (self.fast.free_bytes() or 0) < need:
                return  # tier full of dirty data; the flusher will drain it
        try:
            self.fast.put_object(bucket, key, full, start=t)
        except CosError:
            return
        self.counters["promotions"] += 1

    def head_object(self, bucket: str, key: str,
                    start: float | None = None) -> tuple[int, float]:
        t0 = self.clock.now if start is None else start
        tier = self.tier_of(bucket, key)
        if tier is None:
            raise CosError(f"NoSuchKey: {self.name}://{bucket}/{key}")
        return tier.head_object(bucket, key, start=t0)

    def exists(self, bucket: str, key: str) -> bool:
        return any(t.exists(bucket, key) for t in self.tiers)

    def list_prefix(self, bucket: str, prefix: str, delimiter: str = "/",
                    start: float | None = None
                    ) -> tuple[list[tuple[str, int]], list[str], float]:
        """Union listing: the durable base is authoritative (and charges
        the request), cache tiers contribute residents not yet demoted."""
        t0 = self.clock.now if start is None else start
        objs, prefixes, end = self.base.list_prefix(bucket, prefix,
                                                    delimiter, start=t0)
        merged = dict(objs)
        pfx = set(prefixes)
        for tier in self.tiers[:-1]:
            o2, p2, _ = tier.list_prefix(bucket, prefix, delimiter, start=t0)
            tier.ops["list_prefix"] -= 1  # piggybacked on the base listing
            merged.update(dict(o2))
            pfx.update(p2)
        return sorted(merged.items()), sorted(pfx), end

    def delete_object(self, bucket: str, key: str,
                      start: float | None = None) -> float:
        t0 = self.clock.now if start is None else start
        end = self.base.delete_object(bucket, key, start=t0)
        for tier in self.tiers[:-1]:
            if hasattr(tier, "evict"):
                tier.evict(bucket, key)
            else:  # pragma: no cover
                tier._objects.pop((bucket, key), None)
        self._forget(bucket, key)
        return end

    # ---- MPU: bulk uploads go straight to the durable base ---------------
    def mpu_begin(self, bucket: str, key: str,
                  start: float | None = None) -> tuple[str, float]:
        return self.base.mpu_begin(bucket, key, start=start)

    def mpu_add(self, upload_id: str, part_no: int, data: bytes,
                start: float | None = None) -> float:
        return self.base.mpu_add(upload_id, part_no, data, start=start)

    def mpu_commit(self, upload_id: str,
                   start: float | None = None) -> float:
        mpu = self.base._mpus.get(upload_id)
        end = self.base.mpu_commit(upload_id, start=start)
        if mpu is not None:
            # the durable copy is now newest: never serve a stale cache copy
            for tier in self.tiers[:-1]:
                if hasattr(tier, "evict"):
                    tier.evict(mpu.bucket, mpu.key)
            self._tier_dirty.discard((mpu.bucket, mpu.key))
        return end

    def mpu_abort(self, upload_id: str, start: float | None = None) -> float:
        return self.base.mpu_abort(upload_id, start=start)

    def outstanding_mpus(self) -> list[str]:
        return self.base.outstanding_mpus()

    # ---- failure injection / stats ---------------------------------------
    def fail_next(self, op: str) -> None:
        """Forward to the durable base — the tier the persisting
        transaction talks to (tests target cache tiers directly)."""
        self.base.fail_next(op)

    def tier_dirty_bytes(self) -> int:
        return sum(len(self.fast._objects.get(k, b""))
                   for k in self._tier_dirty)

    def stats(self) -> dict[str, float]:
        out = dict(self.counters)
        out["fast_used_bytes"] = self.fast.used_bytes()
        out["tier_dirty_bytes"] = self.tier_dirty_bytes()
        out["tier_dirty_keys"] = len(self._tier_dirty)
        return out
