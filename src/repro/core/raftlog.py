"""Raft-style two-level write-ahead log (§4.6, Fig. 6).

The paper runs Raft logging *without replication* (single member; replication
is future work) — what it relies on is: (a) a durable, checksummed, append-only
log of state-machine commands with a leader term, replayed after a crash, and
(b) *second-level logs* holding variable-sized bulk payloads (chunk writes),
referenced from primary entries by (file_id, offset, length) so the primary
log stays small.

Primary entry framing (binary, little-endian):

    magic   u32   0x0bjc (0x0b1c0bjc truncated) — 0x0B1C0B1C
    term    u32
    index   u64
    cmd     u32   Cmd enum
    plen    u32   payload length
    crc     u32   crc32 over (term, index, cmd, payload)
    payload bytes JSON (UTF-8) dict, may embed a bulk ref

Replay stops at the first torn/corrupt record (simulated crash may truncate
the tail).  A full-record checksum mismatch *before* the tail is the paper's
"mismatched checksums" case (§3.4): the server refuses to start and the
cluster must be rebuilt from external storage.

Log compaction: `compact(snapshot_payload)` atomically rewrites the log with a
single SNAPSHOT entry carrying the serialized state machine, then truncates
second-level logs that are no longer referenced.
"""

from __future__ import annotations

import json
import os
import struct
import zlib
from dataclasses import dataclass
from typing import Iterator

from .simclock import Resource, SimClock
from .types import Cmd

_MAGIC = 0x0B1C0B1C
_HDR = struct.Struct("<IIQII I".replace(" ", ""))  # magic, term, index, cmd, plen, crc


class ChecksumError(Exception):
    """Non-tail corruption: unrecoverable without external storage (§3.4)."""


@dataclass(frozen=True)
class BulkRef:
    file_id: int
    offset: int
    length: int

    def to_payload(self) -> dict:
        return {"file_id": self.file_id, "offset": self.offset,
                "length": self.length}

    @staticmethod
    def from_payload(p: dict) -> "BulkRef":
        return BulkRef(p["file_id"], p["offset"], p["length"])


@dataclass
class LogEntry:
    term: int
    index: int
    cmd: Cmd
    payload: dict


class RaftLog:
    """Single-member Raft log: durable append + replay + compaction.

    `disk` is the owning node's NVMe `Resource`; every append charges a
    direct-I/O + fsync write (§5: "direct I/O and fsync() after every log
    append").  Time accounting returns the completion timestamp.
    """

    SECOND_LEVEL_FILES = 4  # stripe bulk data over a few files

    def __init__(self, dirpath: str, clock: SimClock, disk: Resource) -> None:
        self.dir = dirpath
        self.clock = clock
        self.disk = disk
        os.makedirs(dirpath, exist_ok=True)
        self.path = os.path.join(dirpath, "raft.log")
        self._f = open(self.path, "ab")
        self.term = self._load_term()
        self.next_index = 1
        self._bulk_files: dict[int, "os.PathLike | str"] = {}
        self._bulk_handles: dict[int, object] = {}
        self._bulk_sizes: dict[int, int] = {}
        for i in range(self.SECOND_LEVEL_FILES):
            p = os.path.join(dirpath, f"bulk.{i}.log")
            self._bulk_files[i] = p
            self._bulk_handles[i] = open(p, "ab")
            self._bulk_sizes[i] = os.path.getsize(p)
        self._next_bulk = 0
        self.appended_bytes = 0

    # ---- term management -------------------------------------------------------
    def _term_path(self) -> str:
        return os.path.join(self.dir, "term")

    def _load_term(self) -> int:
        try:
            with open(self._term_path()) as f:
                return int(f.read().strip() or "1")
        except FileNotFoundError:
            return 1

    def bump_term(self) -> int:
        """A restart is a new 'leadership' of the single member."""
        self.term += 1
        with open(self._term_path(), "w") as f:
            f.write(str(self.term))
        return self.term

    # ---- append ---------------------------------------------------------------
    def append_bulk(self, data: bytes, start: float | None = None
                    ) -> tuple[BulkRef, float]:
        fid = self._next_bulk
        self._next_bulk = (self._next_bulk + 1) % self.SECOND_LEVEL_FILES
        fh = self._bulk_handles[fid]
        off = self._bulk_sizes[fid]
        fh.write(data)
        fh.flush()
        os.fsync(fh.fileno())
        self._bulk_sizes[fid] = off + len(data)
        t0 = self.clock.now if start is None else start
        end = self.disk.acquire(t0, len(data))
        self.appended_bytes += len(data)
        return BulkRef(fid, off, len(data)), end

    def append(self, cmd: Cmd, payload: dict,
               start: float | None = None) -> tuple[int, float]:
        body = json.dumps(payload, separators=(",", ":")).encode()
        idx = self.next_index
        crc = zlib.crc32(struct.pack("<IQI", self.term, idx, int(cmd)) + body)
        rec = _HDR.pack(_MAGIC, self.term, idx, int(cmd), len(body), crc) + body
        self._f.write(rec)
        self._f.flush()
        os.fsync(self._f.fileno())
        self.next_index += 1
        t0 = self.clock.now if start is None else start
        end = self.disk.acquire(t0, len(rec))
        self.appended_bytes += len(rec)
        return idx, end

    def read_bulk(self, ref: BulkRef) -> bytes:
        with open(self._bulk_files[ref.file_id], "rb") as f:
            f.seek(ref.offset)
            data = f.read(ref.length)
        if len(data) != ref.length:
            raise ChecksumError(f"bulk short read: {ref}")
        return data

    # ---- replay -----------------------------------------------------------------
    def replay(self) -> Iterator[LogEntry]:
        """Yields entries up to the first torn tail; raises ChecksumError on
        non-tail corruption."""
        self._f.flush()
        with open(self.path, "rb") as f:
            raw = f.read()
        pos, n = 0, len(raw)
        last_good = 0
        while pos + _HDR.size <= n:
            magic, term, idx, cmd, plen, crc = _HDR.unpack_from(raw, pos)
            if magic != _MAGIC:
                raise ChecksumError(f"bad magic at {pos}")
            end = pos + _HDR.size + plen
            if end > n:
                break  # torn tail: crash mid-append — discard
            body = raw[pos + _HDR.size:end]
            want = zlib.crc32(struct.pack("<IQI", term, idx, cmd) + body)
            if want != crc:
                # corrupt in the middle => unrecoverable; torn at tail => stop
                if end == n:
                    break
                raise ChecksumError(f"crc mismatch at index {idx}")
            yield LogEntry(term, idx, Cmd(cmd), json.loads(body.decode()))
            last_good = idx
            pos = end
        self.next_index = last_good + 1
        # re-open append handle positioned at the last good record
        self._f.close()
        with open(self.path, "rb") as f:
            good = f.read(pos)
        with open(self.path, "wb") as f:
            f.write(good)
        self._f = open(self.path, "ab")

    # ---- compaction -----------------------------------------------------------
    def compact(self, snapshot_payload: dict) -> None:
        """Rewrite the primary log as a single SNAPSHOT entry; bulk files are
        rewritten via the snapshot's embedded data, so they can be truncated."""
        self._f.close()
        with open(self.path, "wb") as f:
            pass
        self._f = open(self.path, "ab")
        self.next_index = 1
        for fid, fh in self._bulk_handles.items():
            fh.close()
            with open(self._bulk_files[fid], "wb"):
                pass
            self._bulk_handles[fid] = open(self._bulk_files[fid], "ab")
            self._bulk_sizes[fid] = 0
        self.append(Cmd.SNAPSHOT, snapshot_payload)

    def size_bytes(self) -> int:
        return (os.path.getsize(self.path)
                + sum(self._bulk_sizes.values()))

    def close(self) -> None:
        self._f.close()
        for fh in self._bulk_handles.values():
            fh.close()

    # crash simulation: truncate the tail of the primary log as if the last
    # append was torn by a power failure
    def simulate_torn_tail(self, nbytes: int = 7) -> None:
        self._f.flush()
        size = os.path.getsize(self.path)
        with open(self.path, "ab") as f:
            f.truncate(max(0, size - nbytes))

    def simulate_corruption(self, at_frac: float = 0.5) -> None:
        self._f.flush()
        size = os.path.getsize(self.path)
        if size < _HDR.size + 4:
            return
        pos = max(_HDR.size, min(size - 2, int(size * at_frac)))
        with open(self.path, "r+b") as f:
            f.seek(pos)
            b = f.read(1)
            f.seek(pos)
            f.write(bytes([b[0] ^ 0xFF]))
