"""Cluster orchestration: bootstrap, elastic scaling, background flush (§4.3).

`Cluster` plays the role of the paper's Kubernetes operator + CSI controller:
it creates/destroys `CacheServer` processes and drives the reconfiguration
transactions.  A node join/leave is:

  1. make affected servers read-only (the paper's migration window),
  2. every server scans for objects whose owner changes under the new ring
     (dirty metadata + dirty chunks migrate; directories always migrate;
     clean objects are dropped — refetchable from COS),
  3. the node-list update commits via the same internal 2PC used for file
     operations, keyed on the reserved `__nodelist__` ring key,
  4. servers become writable again; stale clients see ESTALE and re-pull the
     node list (§4.3).

Scale-down *uploads* dirty data to COS instead of migrating it (§5.5); the
removal of the last node is zero scaling: flush everything and stop — "which
did not need a transaction" (§6.5).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field

from .cos import CosStore
from .flusher import BackgroundFlusher
from .hashring import HashRing
from .net import Router, SimCrash, SimTimeout
from .server import BucketMount, CacheServer, NODELIST_KEY, ServerConfig
from .simclock import HardwareModel, InflightWindow, SimClock
from .types import (Cmd, Errno, FSError, InodeKind, InodeMeta, ROOT_INODE,
                    chunk_key, meta_key)

_CLUSTER_CLIENT_ID = 0  # reserved transaction client id for the operator


@dataclass
class ScaleStats:
    """What one reconfiguration did — feeds Figs. 13/14."""

    op: str = ""
    node: str = ""
    t_start: float = 0.0
    t_end: float = 0.0
    migrated_metas: int = 0
    migrated_dirs: int = 0
    migrated_chunks: int = 0
    migrated_bytes: int = 0
    uploaded_inodes: int = 0

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


class Cluster:
    def __init__(self, workdir: str, buckets: list[BucketMount],
                 hw: HardwareModel | None = None,
                 cfg: ServerConfig | None = None,
                 clock: SimClock | None = None,
                 cos: CosStore | None = None,
                 backends: dict[str, object] | None = None) -> None:
        self.workdir = workdir
        self.buckets = buckets
        self.hw = hw or HardwareModel()
        self.cfg = cfg or ServerConfig()
        self.clock = clock or SimClock()
        self.cos = cos or CosStore(self.clock, self.hw)
        # named storage backends (CosStore / GcsStore / NvmeStore /
        # TieredStore) referenced by BucketMount.backend; the reserved name
        # "cos" always resolves to the swappable default `self.cos`
        self.backends: dict[str, object] = backends or {}
        for bm in buckets:
            assert bm.backend == "cos" or bm.backend in self.backends, \
                f"bucket {bm.bucket!r} bound to unknown backend " \
                f"{bm.backend!r}"
        self.router = Router(self.clock, self.hw, self.cfg.rpc_timeout_s)
        self.servers: dict[str, CacheServer] = {}
        self._next_uid = 1
        self._uids: dict[str, int] = {}
        self._seq = 1
        self.scale_log: list[ScaleStats] = []
        self.flusher = BackgroundFlusher(self)
        os.makedirs(workdir, exist_ok=True)

    # =====================================================================
    # helpers
    # =====================================================================
    def _new_seq(self) -> int:
        self._seq += 1
        return self._seq

    def any_server(self) -> CacheServer:
        for s in self.servers.values():
            if s.alive:
                return s
        raise RuntimeError("no live servers")

    def node_list(self) -> list[str]:
        return self.any_server().node_list if self.servers else []

    def _make_server(self, node_id: str) -> CacheServer:
        uid = self._uids.get(node_id)
        if uid is None:
            uid = self._next_uid
            self._next_uid += 1
            self._uids[node_id] = uid
        s = CacheServer(node_id, uid, os.path.join(self.workdir, node_id),
                        self.clock, self.router, self.cos, self.hw, self.cfg,
                        self.buckets, backends=self.backends)
        self.servers[node_id] = s
        return s

    # =====================================================================
    # bootstrap (first node[s]; "creation ... did not need a transaction")
    # =====================================================================
    def start(self, n_nodes: int = 1, names: list[str] | None = None
              ) -> list[str]:
        assert not self.servers, "cluster already started"
        names = names or [f"n{i}" for i in range(n_nodes)]
        t = self.clock.now
        for nm in names:
            self._make_server(nm)
        ring = HashRing(names)
        nl_op = {"kind": "nodelist_set", "nodes": names, "version": 1}
        for s in self.servers.values():
            t = max(t, s._log(Cmd.LOCAL_META_UPDATE, {"ops": [nl_op]}, t))
        # root inode + one directory per mounted bucket (§3.2: cache servers
        # at first maintain only the root directory with bucket directories)
        root_owner = self.servers[ring.node_for(meta_key(ROOT_INODE))]
        root = InodeMeta(ino=ROOT_INODE, kind=InodeKind.DIR, loaded=True)
        for bm in self.buckets:
            bino = root_owner.alloc_ino()
            root.children[bm.dirname] = bino
            bmeta = InodeMeta(ino=bino, kind=InodeKind.DIR,
                              cos_bucket=bm.bucket, cos_key="", loaded=False)
            owner = self.servers[ring.node_for(meta_key(bino))]
            t = max(t, owner._log(Cmd.LOCAL_META_UPDATE,
                                  {"ops": [{"kind": "meta_put",
                                            "meta": bmeta.to_payload()}]}, t))
        t = max(t, root_owner._log(Cmd.LOCAL_META_UPDATE,
                                   {"ops": [{"kind": "meta_put",
                                             "meta": root.to_payload()}]}, t))
        self.clock.advance_to(t)
        return names

    # =====================================================================
    # node join (§4.3, §5.5 "minimize potential reads after scaling up")
    # =====================================================================
    def add_node(self, node_id: str | None = None) -> ScaleStats:
        node_id = node_id or f"n{len(self._uids)}"
        st = ScaleStats(op="join", node=node_id, t_start=self.clock.now)
        old_nodes = self.node_list()
        assert node_id not in old_nodes
        joiner = self.servers.get(node_id) or self._make_server(node_id)
        new_nodes = sorted(old_nodes + [node_id])
        new_ring = HashRing(new_nodes)
        t = self.clock.now

        # 1. freeze writers on affected nodes ("the node makes FS read-only")
        scans = {}
        for nm in old_nodes:
            s = self.servers[nm]
            scan = s.migration_scan(new_ring)
            if any(scan[k] for k in scan):
                scans[nm] = scan
                _, t = self.router.rpc(None, nm, "rpc_set_read_only", t,
                                       value=True)
        # 2. migrate dirty objects + directories to the joiner
        for nm, scan in scans.items():
            moved, t = self.servers[nm].migrate_out(scan, t)
            st.migrated_metas += moved["metas"]
            st.migrated_dirs += moved["dirs"]
            st.migrated_chunks += moved["chunks"]
            st.migrated_bytes += moved["bytes"]
        # 3. node-list transaction over *all* nodes (§6.5: "our transaction
        #    protocol synchronized the entire node list to every node")
        t = self._commit_node_list(new_nodes, t)
        # 4. thaw
        for nm in scans:
            _, t = self.router.rpc(None, nm, "rpc_set_read_only", t,
                                   value=False)
        self.clock.advance_to(t)
        st.t_end = t
        self.scale_log.append(st)
        return st

    # =====================================================================
    # node leave (§5.5: upload dirty, migrate directories) and zero scaling
    # =====================================================================
    def remove_node(self, node_id: str) -> ScaleStats:
        st = ScaleStats(op="leave", node=node_id, t_start=self.clock.now)
        old_nodes = self.node_list()
        assert node_id in old_nodes
        leaver = self.servers[node_id]
        remaining = [n for n in old_nodes if n != node_id]
        t = self.clock.now

        if not remaining:
            return self.scale_to_zero(st)

        # 1. freeze the leaver, persist every dirty inode it is involved in
        _, t = self.router.rpc(None, node_id, "rpc_set_read_only", t,
                               value=True)
        t, n_up = self._persist_node_dirty(leaver, t)
        st.uploaded_inodes += n_up
        # 2. migrate directories (always) and any residual dirty objects that
        #    could not be uploaded (no COS backing) to their new owners
        new_ring = HashRing(remaining)
        scan = leaver.migration_scan(new_ring)
        moved, t = leaver.migrate_out(scan, t)
        st.migrated_metas += moved["metas"]
        st.migrated_dirs += moved["dirs"]
        st.migrated_chunks += moved["chunks"]
        st.migrated_bytes += moved["bytes"]
        # 3. node-list transaction over the remaining nodes
        t = self._commit_node_list(remaining, t, exclude=node_id)
        # 4. shut the leaver down
        leaver.alive = False
        self.router.unregister(node_id)
        leaver.close()
        del self.servers[node_id]
        self.clock.advance_to(t)
        st.t_end = t
        self.scale_log.append(st)
        return st

    def scale_to_zero(self, st: ScaleStats | None = None) -> ScaleStats:
        """§6.5: the removal of the last node — flush all dirty state to COS
        (files, deletes, and directory markers) and stop.  No transaction."""
        st = st or ScaleStats(op="zero", t_start=self.clock.now)
        st.op = "zero"
        t = self.clock.now
        for s in list(self.servers.values()):
            if not s.alive:
                continue
            _, t = self.router.rpc(None, s.node_id, "rpc_set_read_only", t,
                                   value=True)
            t2, n_up = self._persist_node_dirty(s, t)
            t = max(t, t2)
            st.uploaded_inodes += n_up
        # tiered buckets: demote every cache-tier resident to the durable
        # base — after zero scaling only the durable backends hold data
        for backend in self.backends.values():
            if hasattr(backend, "flush_cache"):
                t = max(t, backend.flush_cache(t))
        for s in list(self.servers.values()):
            s.alive = False
            self.router.unregister(s.node_id)
            s.close()
        self.servers.clear()
        self.clock.advance_to(t)
        st.t_end = t
        self.scale_log.append(st)
        return st

    def _persist_node_dirty(self, s: CacheServer, t: float
                            ) -> tuple[float, int]:
        """Upload every dirty inode `s` owns metadata or chunks for.  The
        persisting coordinator is always the inode's metadata owner.
        Persists are pipelined through the flusher's in-flight window so
        scale-down drains overlap uploads instead of serializing them."""
        inv = s.dirty_inventory()
        inos = set(inv["metas"]) | {ino for ino, _ in inv["chunks"]}
        window = InflightWindow(self.cfg.flush_inflight)
        ends: list[float] = []
        n = 0
        for ino in sorted(inos):
            owner = s.owner(meta_key(ino))
            begin = window.admit(t)
            try:
                res, te = self.router.rpc(None, owner, "coord_persist", begin,
                                          ino=ino,
                                          client_id=_CLUSTER_CLIENT_ID,
                                          seq=self._new_seq())
                if res.get("outcome") in ("commit", "deleted", "dir"):
                    n += 1
            except (SimTimeout, SimCrash, FSError):
                te = begin
            window.settle(te)
            ends.append(te)
        return (max(ends) if ends else t), n

    def _commit_node_list(self, nodes: list[str], t: float,
                          exclude: str | None = None) -> float:
        """2PC the new node list to every participant, coordinated by the
        owner of the reserved __nodelist__ key in the *old* ring."""
        coord_node = self.any_server().owner(NODELIST_KEY)
        if coord_node == exclude or coord_node not in self.servers:
            coord_node = nodes[0]
        coord = self.servers[coord_node]
        version = max(s.node_list_version for s in self.servers.values()) + 1
        op = {"kind": "nodelist_set", "nodes": nodes, "version": version}
        plan = {nm: {"cmd": Cmd.TX_PREPARE_NODELIST, "ops": [op],
                     "keys": [NODELIST_KEY]}
                for nm in nodes}
        res, t = coord.coord_execute(t, _CLUSTER_CLIENT_ID, self._new_seq(),
                                     plan)
        if res["outcome"] != "commit":
            raise FSError(Errno.ECONFLICT, "node-list transaction aborted")
        return t

    # =====================================================================
    # failure handling
    # =====================================================================
    def crash_node(self, node_id: str) -> None:
        self.servers[node_id].crash()

    def restart_node(self, node_id: str) -> float:
        s = self.servers[node_id]
        t = s.restart()
        t = s.recover_pending(t)
        self.clock.advance_to(t)
        return t

    # =====================================================================
    # background write-back ("expiration of dirty objects", §5.2)
    # =====================================================================
    def tick_flush(self, max_inodes: int | None = None,
                   serial: bool = False) -> tuple[int, float]:
        """Persist dirty inodes across the cluster; returns (count, t_end).
        Default path is the pipelined `BackgroundFlusher` (bounded-window
        concurrent persists); `serial=True` keeps the pre-pipeline behaviour
        of threading one virtual time through every inode, retained as the
        before/after baseline for the elasticity reports."""
        if not serial:
            return self.flusher.tick(max_inodes=max_inodes)
        t = self.clock.now
        done = 0
        seen: set[int] = set()
        for s in list(self.servers.values()):
            if not s.alive:
                continue
            for ino in list(s.metas.dirty_inos()):
                if ino in seen or ino == ROOT_INODE:
                    continue
                m = s.metas.get(ino)
                if m is None or s.owner(meta_key(ino)) != s.node_id:
                    continue
                if m.cos_bucket is None or m.cos_key is None:
                    continue
                if m.kind == InodeKind.DIR and not m.deleted:
                    continue  # dirs persist only at zero-scale
                seen.add(ino)
                try:
                    res, t = self.router.rpc(None, s.node_id, "coord_persist",
                                             t, ino=ino,
                                             client_id=_CLUSTER_CLIENT_ID,
                                             seq=self._new_seq())
                    if res.get("outcome") in ("commit", "deleted"):
                        done += 1
                except (SimTimeout, SimCrash, FSError):
                    continue
                if max_inodes is not None and done >= max_inodes:
                    return done, t
        return done, t

    def poll_flush(self) -> tuple[int, float]:
        """Interval-driven flush: runs a pipelined pass only when
        `flush_interval_s` has elapsed on the simclock (or the cluster is
        above its dirty high-watermark)."""
        return self.flusher.poll()

    def drain_dirty(self, max_rounds: int = 8, serial: bool = False) -> int:
        if not serial:
            return self.flusher.drain(max_rounds=max_rounds)
        total = 0
        for _ in range(max_rounds):
            n, t = self.tick_flush(serial=True)
            self.clock.advance_to(t)
            total += n
            if n == 0:
                break
        return total

    # =====================================================================
    # stats
    # =====================================================================
    def total_local_bytes(self) -> int:
        return sum(s.local_bytes() for s in self.servers.values())

    def dirty_counts(self) -> dict:
        metas = sum(len(s.metas.dirty_inos()) for s in self.servers.values())
        chunks = sum(len(s.chunks.dirty_keys()) for s in self.servers.values())
        out = {"dirty_metas": metas, "dirty_chunks": chunks}
        out.update(self.flusher.stats())  # per-tick flusher observability
        for name, backend in sorted(self.backends.items()):
            if hasattr(backend, "stats") and callable(backend.stats):
                out[f"tier.{name}"] = backend.stats()
        return out

    def rpc_stats(self) -> dict[str, dict[str, float]]:
        """Per-method RPC fabric stats (calls / bytes / vtime / timeouts)
        aggregated by the typed dispatch table in the router."""
        return {m: dict(v) for m, v in sorted(self.router.method_stats.items())}

    def close(self) -> None:
        for s in self.servers.values():
            s.close()
