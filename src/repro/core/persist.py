"""Persisting coordinator — Fig. 8's mixed transaction (fsync / flush expiry).

`Persister` uploads a dirty inode to COS and then clears the dirty flags
transactionally.  The multipart upload runs *before* the commit phase so any
failure can abort it; the MPU-begin key is Raft-logged first so a crashed
coordinator can abort the orphan upload at recovery (Fig. 8 black dots).
Sub-chunk inodes whose single chunk is colocated take the PutObject fast
path (§5.2: single participant, single log write).  Deletion propagates as
a COS delete (§5.4), and rename/unlink leftovers are removed via
`_delete_old_keys`.
"""

from __future__ import annotations

from .cos import CosError
from .net import SimCrash, SimTimeout, rpc_handler
from .participant import Participant
from .simclock import InflightWindow
from .state import ServerState
from .types import Cmd, Errno, FSError, InodeKind, InodeMeta, chunk_key


class Persister:
    def __init__(self, state: ServerState, wal: Participant) -> None:
        self.state = state
        self.wal = wal

    @rpc_handler()
    def coord_persist(self, start: float, ino: int, client_id: int, seq: int
                      ) -> tuple[dict, float]:
        """Upload a dirty inode to COS then clear dirty flags transactionally."""
        st = self.state
        st.check_alive()
        m = st.metas.get(ino)
        if m is None:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if not m.dirty and not m.cos_old_keys:
            return {"outcome": "clean"}, start
        if m.cos_bucket is None or m.cos_key is None:
            return {"outcome": "no-backing"}, start  # not bucket-mapped
        t = start

        be = st.backend_for(m.cos_bucket)
        if m.deleted:
            # §5.4: deletion propagates as a COS delete
            t = be.delete_object(m.cos_bucket, m.cos_key, start=t)
            t = self.wal.log(Cmd.COS_DELETE_DONE,
                             {"ino": ino, "key": m.cos_key}, t)
            t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
            return {"outcome": "deleted"}, t

        if m.kind == InodeKind.DIR:
            if not m.cos_key:  # bucket-mount root: nothing to upload
                t = self.wal.log(Cmd.DIRTY_CLEARED_META,
                                 {"ino": ino, "version": m.version}, t)
                return {"outcome": "dir"}, t
            # directory marker object ("key/" suffix denotes a dir, §3.2)
            t = be.put_object(m.cos_bucket,
                              m.cos_key.rstrip("/") + "/", b"", start=t)
            t = self.wal.log(Cmd.PUT_OBJECT_DONE, {"ino": ino}, t)
            t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
            return {"outcome": "dir"}, t

        offsets = st.chunk_offsets(m.size)
        if m.size <= st.cfg.chunk_size and \
                st.owner(chunk_key(ino, 0)) == st.node_id:
            # PutObject fast path (§5.2): single participant, single log write
            data, t = self.materialize_local(ino, 0, m, t)
            try:
                t = be.put_object(m.cos_bucket, m.cos_key, data, start=t)
            except CosError:
                return {"outcome": "abort"}, t
            st.crash_at("persist_after_put")
            t = self.wal.log(Cmd.PUT_OBJECT_DONE, {"ino": ino}, t)
            t = self._delete_old_keys(m, t)
            t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
            st.bump("persist_put")
            return {"outcome": "commit"}, t

        # MPU path: begin -> record key -> pipelined part adds by chunk
        # owners.  Parts fan out so they occupy COS/NIC lanes simultaneously,
        # bounded by the configurable in-flight window (persist_part_window).
        try:
            upload_id, t = be.mpu_begin(m.cos_bucket, m.cos_key, start=t)
        except CosError:
            return {"outcome": "abort"}, t
        t = self.wal.log(Cmd.MPU_BEGIN_RECORDED,
                         {"ino": ino, "upload_id": upload_id,
                          "bucket": m.cos_bucket, "key": m.cos_key}, t)
        st.crash_at("persist_after_mpu_begin")
        window = InflightWindow(st.cfg.persist_part_window)
        ends, ok = [], True
        for part_no, coff in enumerate(offsets, start=1):
            owner = st.owner(chunk_key(ino, coff))
            ln = min(st.cfg.chunk_size, m.size - coff)
            begin = window.admit(t)
            try:
                if owner == st.node_id:
                    data, te = self.materialize_local(ino, coff, m, begin)
                    te = be.mpu_add(upload_id, part_no, data, start=te)
                else:
                    # the part payload travels owner->COS inside the handler;
                    # declare it so fabric byte accounting stays truthful
                    _, te = st.router.rpc(
                        st.node_id, owner, "rpc_upload_part", begin,
                        nbytes_out=256, nbytes_extra=ln,
                        ino=ino, chunk_off=coff, length=ln,
                        upload_id=upload_id, part_no=part_no,
                        cos_bucket=m.cos_bucket, cos_key=m.cos_key,
                        file_size=m.size)
            except (SimTimeout, SimCrash, CosError):
                te = st.router.charge_timeout(begin)
                ok = False
            window.settle(te)
            ends.append(te)
        t = max(ends) if ends else t
        if not ok:
            t = self._abort_mpu(be, upload_id, t)
            st.bump("persist_abort")
            return {"outcome": "abort"}, t
        try:
            t = be.mpu_commit(upload_id, start=t)
        except CosError:
            t = self._abort_mpu(be, upload_id, t)
            return {"outcome": "abort"}, t
        st.crash_at("persist_after_mpu_commit")
        t = self.wal.log(Cmd.MPU_COMMITTED,
                         {"ino": ino, "upload_id": upload_id}, t)
        t = self._delete_old_keys(m, t)
        t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
        st.bump("persist_mpu")
        return {"outcome": "commit"}, t

    def materialize_local(self, ino: int, coff: int, m: InodeMeta,
                          start: float) -> tuple[bytes, float]:
        st = self.state
        ln = min(st.cfg.chunk_size, m.size - coff)
        c = st.chunks.get(ino, coff)
        t = start
        if c is None or not c.covered(0, ln):
            be = st.backend_for(m.cos_bucket)
            if m.cos_key is not None and be.exists(m.cos_bucket, m.cos_key):
                data, t = be.get_object(m.cos_bucket, m.cos_key,
                                        rng=(coff, ln), start=t)
                ref, t = st.raft.append_bulk(data, start=t)
                t = self.wal.log(Cmd.CHUNK_FILL_FROM_COS,
                                 {"ino": ino, "chunk_off": coff, "off": 0,
                                  "length": len(data),
                                  "ref": ref.to_payload()}, t)
                c = st.chunks.get(ino, coff)
        if c is None:
            return b"\0" * ln, t
        t = st.disk.acquire(t, ln)
        return c.materialize(st.raft, ln), t

    @rpc_handler()
    def rpc_upload_part(self, start: float, ino: int, chunk_off: int,
                        length: int, upload_id: str, part_no: int,
                        cos_bucket: str, cos_key: str, file_size: int
                        ) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        m = InodeMeta(ino=ino, kind=InodeKind.FILE, size=file_size,
                      cos_bucket=cos_bucket, cos_key=cos_key)
        data, t = self.materialize_local(ino, chunk_off, m, start)
        t = st.backend_for(cos_bucket).mpu_add(upload_id, part_no,
                                               data[:length], start=t)
        st.bump("mpu_part")
        return {"ok": True}, t

    def _abort_mpu(self, backend, upload_id: str, start: float) -> float:
        """Abort an upload at its backend and retire the pending record so
        replay does not resurrect it as an orphan."""
        t = backend.mpu_abort(upload_id, start=start)
        return self.wal.log(Cmd.MPU_ABORTED, {"upload_id": upload_id}, t)

    def recover_orphan_mpus(self, start: float) -> float:
        """Abort every MPU whose begin was Raft-logged but that never reached
        commit/abort — the Fig. 8 recovery consuming MPU_BEGIN_RECORDED.
        Idempotent: COS abort of an unknown upload id is a no-op."""
        st = self.state
        t = start
        for upload_id in sorted(st.mpu_pending):
            be = st.backend_for(st.mpu_pending[upload_id].get("bucket"))
            try:
                t = be.mpu_abort(upload_id, start=t)
            except CosError:
                continue  # retried at the next recovery pass
            t = self.wal.log(Cmd.MPU_ABORTED, {"upload_id": upload_id}, t)
            st.bump("mpu_orphan_aborted")
        return t

    def _delete_old_keys(self, m: InodeMeta, start: float) -> float:
        st = self.state
        t = start
        be = st.backend_for(m.cos_bucket)
        for old in m.cos_old_keys:
            if old != m.cos_key:
                t = be.delete_object(m.cos_bucket, old, start=t)
                t = self.wal.log(Cmd.COS_DELETE_DONE,
                                 {"ino": m.ino, "key": old}, t)
        return t

    def _clear_dirty_everywhere(self, ino: int, m: InodeMeta, start: float,
                                client_id: int, seq: int) -> float:
        """Commit phase of Fig. 8: clear chunk dirty flags, then metadata.
        Version guards make the clears safe against racing writers (§5.2).
        All clears bound for one chunk owner ride one batched envelope, so
        a K-chunk inode costs O(owners) messages instead of O(chunks)."""
        st = self.state
        t = start
        ends = []
        by_owner: dict[str, list[dict]] = {}
        for coff in st.chunk_offsets(m.size):
            owner = st.owner(chunk_key(ino, coff))
            if owner == st.node_id:
                c = st.chunks.get(ino, coff)
                if c is not None:
                    ends.append(self.wal.log(Cmd.DIRTY_CLEARED_CHUNK,
                                             {"ino": ino, "chunk_off": coff,
                                              "version": c.version}, t))
            else:
                by_owner.setdefault(owner, []).append(
                    {"method": "rpc_clear_chunk_dirty",
                     "kwargs": {"ino": ino, "chunk_off": coff}})
        for owner, calls in sorted(by_owner.items()):
            try:
                if st.cfg.batch_rpcs:
                    _, te = st.router.rpc_batch(st.node_id, owner, calls, t)
                    ends.append(te)
                else:
                    for c in calls:
                        _, te = st.router.rpc(st.node_id, owner,
                                              c["method"], t, **c["kwargs"])
                        ends.append(te)
            except (SimTimeout, SimCrash):
                ends.append(st.router.charge_timeout(t))
        t = max(ends) if ends else t
        t = self.wal.log(Cmd.DIRTY_CLEARED_META, {"ino": ino,
                                                  "version": m.version}, t)
        return t

    @rpc_handler()
    def rpc_clear_chunk_dirty(self, start: float, ino: int, chunk_off: int
                              ) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        c = st.chunks.get(ino, chunk_off)
        if c is None:
            return {"ok": True}, start
        t = self.wal.log(Cmd.DIRTY_CLEARED_CHUNK,
                         {"ino": ino, "chunk_off": chunk_off,
                          "version": c.version}, start)
        return {"ok": True}, t
