"""WAL state machine + 2PC participant role (§4.4–4.5).

`Participant` owns the *only* write path into working state: every mutation
flows through `log()` (durable Raft append, then `apply()`), so a crashed
server rebuilds exactly by replaying the log through the same `apply()`.
It also implements the participant half of the internal 2PC —
`rpc_prepare` / `rpc_commit` / `rpc_abort` with TxId dedup (§4.5: a retried
RPC series with the same TxId replies with the old result).
"""

from __future__ import annotations

from .hashring import HashRing
from .net import rpc_handler
from .raftlog import BulkRef
from .state import ServerState
from .stores import ChunkState, Segment, StagedWrite
from .txn import PreparedOp, PreparedTx, txid_from_payload
from .types import Cmd, InodeMeta


class Participant:
    def __init__(self, state: ServerState) -> None:
        self.state = state

    # =====================================================================
    # durable log + state machine
    # =====================================================================
    def log(self, cmd: Cmd, payload: dict, start: float) -> float:
        _, end = self.state.raft.append(cmd, payload, start=start)
        self.apply(cmd, payload)
        return end

    def replay(self, start: float) -> float:
        """Rebuild all working state from the WAL (§3.4); returns the time
        after charging a sequential disk read of the whole log."""
        st = self.state
        st.reset_tables()
        for entry in st.raft.replay():
            self.apply(entry.cmd, entry.payload)
        st.raft.bump_term()
        return st.disk.acquire(start, st.raft.size_bytes())

    def apply(self, cmd: Cmd, p: dict) -> None:
        st = self.state
        if cmd in (Cmd.TX_PREPARE_META, Cmd.TX_PREPARE_CHUNK,
                   Cmd.TX_PREPARE_DIR, Cmd.TX_PREPARE_NODELIST):
            txid = txid_from_payload(p["txid"])
            tx = st.txs.prepared.get(txid) or PreparedTx(txid)
            for op in p["ops"]:
                tx.ops.append(PreparedOp(cmd, op))
            keys = p.get("keys", [])
            tx.locked_keys.extend(keys)
            st.locks.try_acquire(keys, txid)
            st.txs.put_prepared(tx)
        elif cmd == Cmd.TX_COMMIT:
            txid = txid_from_payload(p["txid"])
            tx = st.txs.pop_prepared(txid)
            if tx is not None:
                for op in tx.ops:
                    self.apply_op(op.payload)
            st.locks.release(txid, now=st.clock.now)
            st.txs.record_completed(txid, "commit")
        elif cmd == Cmd.TX_ABORT:
            txid = txid_from_payload(p["txid"])
            st.txs.pop_prepared(txid)
            st.locks.release(txid, now=st.clock.now)
            st.txs.record_completed(txid, "abort")
        elif cmd in (Cmd.LOCAL_META_UPDATE, Cmd.LOCAL_CHUNK_COMMIT,
                     Cmd.LOCAL_DIR_UPDATE):
            for op in p["ops"]:
                self.apply_op(op)
        elif cmd == Cmd.CHUNK_STAGE:
            c = st.chunks.ensure(p["ino"], p["chunk_off"])
            c.staged[p["stage_id"]] = StagedWrite(
                p["stage_id"], p["off"], p["length"],
                BulkRef.from_payload(p["ref"]))
        elif cmd == Cmd.CHUNK_FILL_FROM_COS:
            c = st.chunks.ensure(p["ino"], p["chunk_off"])
            c.base_filled.append(Segment(p["off"], p["length"],
                                         BulkRef.from_payload(p["ref"])))
        elif cmd in (Cmd.EVICT_META,):
            st.metas.evict(p["ino"])
            st.bump_lease(p["ino"])
        elif cmd in (Cmd.EVICT_CHUNK,):
            st.chunks.evict(p["ino"], p["chunk_off"])
        elif cmd == Cmd.MIGRATE_RECV_META or cmd == Cmd.MIGRATE_RECV_DIR:
            # migration handoff invalidates leases the old owner granted:
            # the receiver starts a fresh epoch strictly above anything a
            # client could still hold for this inode
            meta = InodeMeta.from_payload(p["meta"])
            st.metas.put(meta)
            st.note_ino(meta.ino)
            st.bump_lease(meta.ino)
        elif cmd == Cmd.MIGRATE_RECV_CHUNK:
            c = ChunkState.from_payload(p["chunk"])
            st.chunks.chunks[(c.ino, c.chunk_off)] = c
        elif cmd == Cmd.TX_COORD_BEGIN:
            st.txseq = max(st.txseq, p["txid"]["txseq"] + 1)
            st.coord_pending[p["txid"]["txseq"]] = {
                "txid": p["txid"], "nodes": p["nodes"], "decided": None}
        elif cmd == Cmd.TX_COORD_DECIDE_COMMIT:
            info = st.coord_pending.get(p["txseq"])
            if info is not None:
                info["decided"] = "commit"
            st.coord_done[(p["client_id"], p["seq"])] = (p["txseq"], "commit")
        elif cmd == Cmd.TX_COORD_DECIDE_ABORT:
            info = st.coord_pending.get(p["txseq"])
            if info is not None:
                info["decided"] = "abort"
            st.coord_done[(p["client_id"], p["seq"])] = (p["txseq"], "abort")
        elif cmd == Cmd.MPU_BEGIN_RECORDED:
            # tracked until MPU_COMMITTED/MPU_ABORTED so a restarted
            # coordinator aborts the orphan upload (recover_orphan_mpus)
            st.mpu_pending[p["upload_id"]] = {
                "ino": p["ino"], "bucket": p["bucket"], "key": p["key"]}
        elif cmd in (Cmd.MPU_COMMITTED, Cmd.MPU_ABORTED):
            st.mpu_pending.pop(p["upload_id"], None)
        elif cmd in (Cmd.PUT_OBJECT_DONE, Cmd.COS_DELETE_DONE):
            pass  # audit records
        elif cmd in (Cmd.DIRTY_CLEARED_CHUNK,):
            c = st.chunks.get(p["ino"], p["chunk_off"])
            if c is not None and c.version == p["version"]:
                c.dirty = False
        elif cmd in (Cmd.DIRTY_CLEARED_META,):
            m = st.metas.get(p["ino"])
            if m is not None and m.version == p["version"]:
                m.dirty = False
                m.cos_old_keys = []
        elif cmd == Cmd.NODE_JOIN or cmd == Cmd.NODE_LEAVE:
            pass  # audit-only; the node list itself moves via nodelist_set ops
        elif cmd == Cmd.SNAPSHOT:
            self.load_snapshot(p)
        else:  # pragma: no cover
            raise AssertionError(f"unknown cmd {cmd}")

    def apply_op(self, op: dict) -> None:
        """Redo-op application — the only place working state mutates.
        Every committed metadata/namespace mutation bumps the inode's lease
        epoch here, so client leases invalidate on the same apply path that
        WAL replay re-runs (a restarted owner re-derives identical epochs)."""
        st = self.state
        kind = op["kind"]
        if kind in ("meta_put", "meta_set", "meta_evict", "dir_link",
                    "dir_set_children", "dir_unlink"):
            st.bump_lease(op["meta"]["ino"] if kind == "meta_put"
                          else op["ino"])
        if kind == "meta_put":
            meta = InodeMeta.from_payload(op["meta"])
            st.metas.put(meta)
            st.note_ino(meta.ino)
        elif kind == "meta_set":
            m = st.metas.get(op["ino"])
            if m is None:
                return
            for f in ("size", "mtime", "dirty", "deleted", "mode",
                      "cos_bucket", "cos_key", "loaded"):
                if f in op:
                    setattr(m, f, op[f])
            if "add_old_key" in op and op["add_old_key"]:
                if op["add_old_key"] not in m.cos_old_keys:
                    m.cos_old_keys.append(op["add_old_key"])
            m.version += 1
        elif kind == "meta_evict":
            st.metas.evict(op["ino"])
        elif kind == "dir_link":
            d = st.metas.get(op["ino"])
            if d is None:
                return
            d.children[op["name"]] = op["child"]
            d.mtime = op.get("mtime", d.mtime)
            d.version += 1
            d.dirty = True
        elif kind == "dir_set_children":
            d = st.metas.get(op["ino"])
            if d is None:
                return
            d.children.update({k: int(v) for k, v in op["children"].items()})
            d.loaded = bool(op.get("loaded", d.loaded))
            d.version += 1
        elif kind == "dir_unlink":
            d = st.metas.get(op["ino"])
            if d is None:
                return
            d.children.pop(op["name"], None)
            d.mtime = op.get("mtime", d.mtime)
            d.version += 1
            d.dirty = True
        elif kind == "chunk_promote":
            c = st.chunks.ensure(op["ino"], op["chunk_off"])
            for sid in op["stage_ids"]:
                sw = c.staged.pop(sid, None)
                if sw is not None:
                    c.segments.append(Segment(sw.off, sw.length, sw.ref))
            c.version += 1
            c.dirty = True
            c.deleted = False
        elif kind == "chunk_zero_tail":
            c = st.chunks.ensure(op["ino"], op["chunk_off"])
            c.segments.append(Segment(op["from"], op["length"], None))
            c.version += 1
            c.dirty = True
        elif kind == "chunk_delete":
            c = st.chunks.ensure(op["ino"], op["chunk_off"])
            c.deleted = True
            c.dirty = True
            c.version += 1
            c.base_filled, c.segments, c.staged = [], [], {}
        elif kind == "chunk_evict":
            st.chunks.evict(op["ino"], op["chunk_off"])
        elif kind == "nodelist_set":
            st.node_list = list(op["nodes"])
            st.node_list_version = op["version"]
            st.ring = HashRing(st.node_list)
        else:  # pragma: no cover
            raise AssertionError(f"unknown op kind {kind}")

    # ---- snapshot/compaction -------------------------------------------------
    def snapshot_payload(self) -> dict:
        st = self.state
        return {
            "node_list": st.node_list, "nl_version": st.node_list_version,
            "ino_counter": st.ino_counter,
            "metas": {str(i): m.to_payload()
                      for i, m in st.metas.inodes.items()},
        }

    def load_snapshot(self, p: dict) -> None:
        st = self.state
        st.node_list = list(p["node_list"])
        st.node_list_version = p["nl_version"]
        st.ring = HashRing(st.node_list)
        st.ino_counter = p["ino_counter"]
        for mp in p["metas"].values():
            st.metas.put(InodeMeta.from_payload(mp))

    # =====================================================================
    # 2PC participant RPCs (§4.4)
    # =====================================================================
    @rpc_handler(request_bytes=512)
    def rpc_prepare(self, start: float, txid_p: dict, cmd_id: int, ops: list,
                    keys: list, nl_version: int | None = None
                    ) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        txid = txid_from_payload(txid_p)
        done = st.txs.completed_outcome(txid)
        if done is not None:  # duplicated request (§4.5) — reply old result
            return {"vote": done == "commit", "dup": True}, start
        if st.txs.is_prepared(txid):  # retried prepare: already voted yes
            return {"vote": True, "dup": True}, start
        if Cmd(cmd_id) != Cmd.TX_PREPARE_NODELIST:
            # reconfiguration transactions run *during* the read-only window
            st.check_writable()
        verdict = st.locks.acquire(list(keys), txid, now=start,
                                   wait_die=st.cfg.lock_mode == "waitdie")
        if verdict != "granted":
            # wait-die (§4.4 refined): an older transaction keeps its FIFO
            # place ("queued") and is handed the lock at release, so its
            # retry — same TxId — wins; a younger one dies immediately.
            # Either way this attempt votes no and the coordinator aborts.
            st.bump("lock_conflict")
            st.bump(f"lock_{verdict}")
            return {"vote": False, "why": verdict}, start
        st.crash_at("participant_after_lock")
        t = self.log(Cmd(cmd_id), {"txid": txid_p, "ops": ops, "keys": keys},
                     start)
        st.crash_at("participant_after_prepare")
        return {"vote": True}, t

    @rpc_handler()
    def rpc_commit(self, start: float, txid_p: dict) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        txid = txid_from_payload(txid_p)
        if st.txs.completed_outcome(txid) is not None:
            return {"ok": True, "dup": True}, start
        t = self.log(Cmd.TX_COMMIT, {"txid": txid_p}, start)
        st.crash_at("participant_after_commit")
        return {"ok": True}, t

    @rpc_handler()
    def rpc_abort(self, start: float, txid_p: dict) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        txid = txid_from_payload(txid_p)
        if st.txs.completed_outcome(txid) is not None:
            return {"ok": True, "dup": True}, start
        if not st.txs.is_prepared(txid):
            # never prepared here: nothing redo-logged to undo, and a
            # "queued" vote must keep its wait-queue place for the retry
            return {"ok": True, "noop": True}, start
        t = self.log(Cmd.TX_ABORT, {"txid": txid_p}, start)
        return {"ok": True}, t
