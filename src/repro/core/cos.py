"""Simulated external object storage — pluggable multi-backend layer.

Implements the API subset objcache needs (§5.2): PutObject, GetObject with
range reads, ListObjectsV2-style prefix+delimiter listing, DeleteObject, and
multipart upload (begin / add part / commit / abort).  Backed by an
in-memory dict of real bytes; timing charged against a per-backend
`Resource` lane (per-request latency + per-connection bandwidth with bounded
parallelism).

Since PR 10 the single regional-bucket model is one *profile* of a shared
`ObjectBackend` base.  Three concrete profiles ship:

* `CosStore` — S3-like: high request latency, high aggregate throughput,
  MPU required above ``put_limit_bytes`` (when a profile sets one);
* `GcsStore` — GCS-like: a different latency/bandwidth lane (fewer, faster
  connections) plus a connection *slow-start* ramp on the first requests;
* `NvmeStore` — local-NVMe cache tier: microsecond latency, **bounded
  capacity** (`CosCapacityError` when a put would overflow) — the fast
  tier `core/tiering.py` promotes into and demotes out of.

Contracts every backend honours (and `tests/test_tiering.py` asserts):

* **One lane per backend.**  Each backend owns exactly one `Resource`; all
  timing flows through ``self.resource.acquire`` (plus deterministic
  retry/slow-start penalties), so two backends never contend with each
  other and a tiered read/write charges each tier's own lane.
* **Deterministic failure profiles.**  `fail_next(op)` injects one hard
  `CosError` (the Fig. 8 black-dot crashes); `BackendProfile.throttle_every
  = N` makes every Nth invocation of a throttled op hit a retryable
  `CosThrottleError` (503/SlowDown).  With ``max_retries > 0`` the backend
  retries *internally* — each attempt charges one extra request latency
  plus ``retry_backoff_s`` of virtual time and bumps ``stats``
  (``retries``) — and only raises once retries are exhausted.  Same seed,
  same op sequence → same virtual end times, always.
* **Capacity accounting is exact.**  `used_bytes` counts stored objects
  plus uncommitted MPU parts; `put_object`/`mpu_add` raise
  `CosCapacityError` *before* mutating state when the write would exceed
  ``capacity_bytes``, so a failed put never half-lands.  Deletes free
  capacity immediately.

The default `CosStore()` (no profile overrides) is byte- and
virtual-time-identical to the pre-PR-10 single store: same resource
parameters from `HardwareModel.make_cos`, no extra acquires, no penalties —
the single-backend metamorphic test pins this.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace

from .simclock import HardwareModel, Resource, SimClock


class CosError(Exception):
    """Hard, non-retryable storage failure (NoSuchKey, injected faults)."""


class CosThrottleError(CosError):
    """Retryable throttle (S3 503 SlowDown / GCS 429): the backend retries
    internally up to ``profile.max_retries`` before surfacing this."""


class CosCapacityError(CosError):
    """A put would exceed the backend's ``capacity_bytes`` (NVMe tier full);
    callers (the tiering engine) must demote/evict before retrying."""


@dataclass(frozen=True)
class BackendProfile:
    """Latency / bandwidth / failure envelope of one storage backend.

    ``latency_s`` + ``conn_bps`` + ``parallelism`` parameterize the
    backend's `Resource` lane.  The failure knobs are all *off* by default
    so the default profile reproduces the pre-PR-10 store exactly:

    * ``throttle_every`` — every Nth data-plane request raises a retryable
      `CosThrottleError` (0 disables);
    * ``max_retries`` / ``retry_backoff_s`` — internal retry budget per
      request; each retry charges one extra ``latency_s`` +
      ``retry_backoff_s``;
    * ``slow_start_ops`` / ``slow_start_factor`` — the first N transfers
      pay ``factor``× the bandwidth cost (cold HTTP connections);
    * ``capacity_bytes`` — bound on stored + in-flight bytes (None =
      unbounded);
    * ``put_limit_bytes`` — single PutObject size cap (MPU required above
      it, as real S3 enforces at 5 GiB; None = uncapped).
    """

    name: str = "cos"
    latency_s: float = 30e-3
    conn_bps: float = 120e6
    parallelism: int = 64
    throttle_every: int = 0
    max_retries: int = 0
    retry_backoff_s: float = 0.05
    slow_start_ops: int = 0
    slow_start_factor: float = 2.0
    capacity_bytes: int | None = None
    put_limit_bytes: int | None = None
    durable: bool = True


# ops whose Nth-request counter the throttle profile polices (data plane
# only: control ops like exists() are free probes in the sim)
_THROTTLED_OPS = ("put_object", "get_object", "mpu_add", "mpu_commit")


@dataclass
class _MPU:
    bucket: str
    key: str
    upload_id: str
    parts: dict[int, bytes] = field(default_factory=dict)

    def bytes(self) -> int:
        return sum(len(p) for p in self.parts.values())


class ObjectBackend:
    """One external storage endpoint holding many buckets.

    Subclasses pin a `BackendProfile` (and with it a `Resource` lane);
    everything else — the in-memory data plane, MPU machinery, failure
    injection, stats — is shared here.
    """

    profile_defaults = BackendProfile()

    def __init__(self, clock: SimClock,
                 profile: BackendProfile | None = None,
                 resource: Resource | None = None) -> None:
        self.clock = clock
        self.profile = profile or self.profile_defaults
        p = self.profile
        self.resource: Resource = resource or Resource(
            p.name, p.conn_bps, p.latency_s, p.parallelism)
        self._objects: dict[tuple[str, str], bytes] = {}
        self._mpus: dict[str, _MPU] = {}
        self._upload_ids = itertools.count(1)
        # failure injection: set of op names that fail once when next invoked
        self._fail_once: set[str] = set()
        self._throttle_seen = 0
        self._transfers_seen = 0
        # stats
        self.ops: dict[str, int] = {}
        self.bytes_in = 0
        self.bytes_out = 0
        self.stats: dict[str, float] = {}

    @property
    def name(self) -> str:
        return self.profile.name

    @property
    def durable(self) -> bool:
        return self.profile.durable

    # ---- capacity accounting ---------------------------------------------
    def used_bytes(self) -> int:
        """Stored objects plus uncommitted MPU parts — the quantity
        ``capacity_bytes`` bounds."""
        return sum(len(v) for v in self._objects.values()) + \
            sum(m.bytes() for m in self._mpus.values())

    def free_bytes(self) -> int | None:
        cap = self.profile.capacity_bytes
        return None if cap is None else cap - self.used_bytes()

    def object_count(self, bucket: str | None = None) -> int:
        if bucket is None:
            return len(self._objects)
        return sum(1 for (b, _k) in self._objects if b == bucket)

    def _check_capacity(self, incoming: int, replacing: int = 0) -> None:
        cap = self.profile.capacity_bytes
        if cap is not None and \
                self.used_bytes() - replacing + incoming > cap:
            raise CosCapacityError(
                f"{self.name}: put of {incoming}B exceeds capacity "
                f"{cap}B (used {self.used_bytes()}B)")

    # ---- failure injection -------------------------------------------------
    def fail_next(self, op: str) -> None:
        self._fail_once.add(op)

    def _bump(self, stat: str, n: float = 1) -> None:
        self.stats[stat] = self.stats.get(stat, 0) + n

    def _admit(self, op: str, t0: float) -> float:
        """Count the request, apply one-shot injected failures, and run the
        deterministic throttle/retry schedule.  Returns the (possibly
        backoff-delayed) time the request's transfer may begin; raises
        when the failure is non-retryable or retries are exhausted."""
        self.ops[op] = self.ops.get(op, 0) + 1
        if op in self._fail_once:
            self._fail_once.discard(op)
            raise CosError(f"injected failure: {op}")
        p = self.profile
        if p.throttle_every and op in _THROTTLED_OPS:
            self._throttle_seen += 1
            if self._throttle_seen % p.throttle_every == 0:
                # every Nth data-plane request hits one throttle event;
                # with a retry budget the *next* attempt succeeds
                if p.max_retries <= 0:
                    self._bump("throttles")
                    raise CosThrottleError(f"{self.name}: SlowDown ({op})")
                self._bump("throttles")
                self._bump("retries")
                t0 = t0 + p.latency_s + p.retry_backoff_s
        return t0

    def _transfer_penalty(self, nbytes: int) -> float:
        """Extra seconds for connection slow-start on the first transfers."""
        p = self.profile
        if nbytes and self._transfers_seen <= p.slow_start_ops:
            self._bump("slow_starts")
            return (p.slow_start_factor - 1.0) * nbytes / p.conn_bps
        return 0.0

    def _charge(self, t0: float, nbytes: int) -> float:
        """Book the transfer on this backend's lane (+ slow-start ramp)."""
        if nbytes:
            self._transfers_seen += 1
        return self.resource.acquire(t0, nbytes) + \
            self._transfer_penalty(nbytes)

    # ---- data plane ----------------------------------------------------------
    def make_bucket(self, bucket: str) -> None:
        # buckets are implicit; kept for API parity
        self._admit("make_bucket", 0.0)

    def put_object(self, bucket: str, key: str, data: bytes,
                   start: float | None = None) -> float:
        t0 = self.clock.now if start is None else start
        t0 = self._admit("put_object", t0)
        lim = self.profile.put_limit_bytes
        if lim is not None and len(data) > lim:
            raise CosError(f"{self.name}: EntityTooLarge ({len(data)}B > "
                           f"{lim}B); use multipart upload")
        old = self._objects.get((bucket, key))
        self._check_capacity(len(data), replacing=len(old) if old else 0)
        end = self._charge(t0, len(data))
        self._objects[(bucket, key)] = bytes(data)
        self.bytes_in += len(data)
        return end

    def get_object(self, bucket: str, key: str,
                   rng: tuple[int, int] | None = None,
                   start: float | None = None) -> tuple[bytes, float]:
        """rng = (offset, length) half-open byte range."""
        t0 = self.clock.now if start is None else start
        t0 = self._admit("get_object", t0)
        obj = self._objects.get((bucket, key))
        if obj is None:
            raise CosError(f"NoSuchKey: {self.name}://{bucket}/{key}")
        if rng is not None:
            off, ln = rng
            data = obj[off:off + ln]
        else:
            data = obj
        end = self._charge(t0, len(data))
        self.bytes_out += len(data)
        return data, end

    def head_object(self, bucket: str, key: str,
                    start: float | None = None) -> tuple[int, float]:
        t0 = self.clock.now if start is None else start
        t0 = self._admit("head_object", t0)
        obj = self._objects.get((bucket, key))
        if obj is None:
            raise CosError(f"NoSuchKey: {self.name}://{bucket}/{key}")
        return len(obj), self._charge(t0, 0)

    def exists(self, bucket: str, key: str) -> bool:
        return (bucket, key) in self._objects

    def list_prefix(self, bucket: str, prefix: str, delimiter: str = "/",
                    start: float | None = None
                    ) -> tuple[list[tuple[str, int]], list[str], float]:
        """Returns (objects=[(key,size)...], common_prefixes, t_end); COS has
        no directories — keys under `prefix` up to `delimiter` (§3.2, §5.4)."""
        t0 = self.clock.now if start is None else start
        t0 = self._admit("list_prefix", t0)
        objs: list[tuple[str, int]] = []
        prefixes: set[str] = set()
        for (b, k), v in self._objects.items():
            if b != bucket or not k.startswith(prefix):
                continue
            rest = k[len(prefix):]
            if not rest:
                objs.append((k, len(v)))
                continue
            if delimiter and delimiter in rest:
                prefixes.add(prefix + rest.split(delimiter, 1)[0] + delimiter)
            else:
                objs.append((k, len(v)))
        end = self._charge(t0, 0)
        return sorted(objs), sorted(prefixes), end

    def delete_object(self, bucket: str, key: str,
                      start: float | None = None) -> float:
        t0 = self.clock.now if start is None else start
        t0 = self._admit("delete_object", t0)
        self._objects.pop((bucket, key), None)  # S3 delete is idempotent
        return self._charge(t0, 0)

    # ---- multipart upload (§5.2) ---------------------------------------------
    def mpu_begin(self, bucket: str, key: str,
                  start: float | None = None) -> tuple[str, float]:
        t0 = self.clock.now if start is None else start
        t0 = self._admit("mpu_begin", t0)
        uid = f"mpu-{next(self._upload_ids)}"
        self._mpus[uid] = _MPU(bucket, key, uid)
        return uid, self._charge(t0, 0)

    def mpu_add(self, upload_id: str, part_no: int, data: bytes,
                start: float | None = None) -> float:
        t0 = self.clock.now if start is None else start
        t0 = self._admit("mpu_add", t0)
        mpu = self._mpus.get(upload_id)
        if mpu is None:
            raise CosError(f"NoSuchUpload: {upload_id}")
        old = mpu.parts.get(part_no)
        self._check_capacity(len(data), replacing=len(old) if old else 0)
        mpu.parts[part_no] = bytes(data)
        self.bytes_in += len(data)
        return self._charge(t0, len(data))

    def mpu_commit(self, upload_id: str,
                   start: float | None = None) -> float:
        t0 = self.clock.now if start is None else start
        t0 = self._admit("mpu_commit", t0)
        mpu = self._mpus.pop(upload_id, None)
        if mpu is None:
            raise CosError(f"NoSuchUpload: {upload_id}")
        blob = b"".join(mpu.parts[i] for i in sorted(mpu.parts))
        self._objects[(mpu.bucket, mpu.key)] = blob
        return self._charge(t0, 0)

    def mpu_abort(self, upload_id: str, start: float | None = None) -> float:
        t0 = self.clock.now if start is None else start
        t0 = self._admit("mpu_abort", t0)
        self._mpus.pop(upload_id, None)  # idempotent
        return self._charge(t0, 0)

    def outstanding_mpus(self) -> list[str]:
        return sorted(self._mpus)


class CosStore(ObjectBackend):
    """S3-like regional bucket — the paper's single external store.

    Keeps the historical constructor ``CosStore(clock, hw)`` so every
    existing cluster/benchmark/test builds the exact same backend: the
    `Resource` comes from `HardwareModel.make_cos` (30 ms request latency,
    120 MB/s per connection, 64 connections) and all failure knobs are off
    unless a profile overrides them.
    """

    def __init__(self, clock: SimClock, hw: HardwareModel | None = None,
                 profile: BackendProfile | None = None) -> None:
        self.hw = hw or HardwareModel()
        if profile is None:
            profile = BackendProfile(
                name="cos", latency_s=self.hw.cos_latency_s,
                conn_bps=self.hw.cos_conn_bps,
                parallelism=self.hw.cos_parallelism)
        super().__init__(clock, profile,
                         resource=self.hw.make_lane(
                             profile.name, profile.conn_bps,
                             profile.latency_s, profile.parallelism))


GCS_PROFILE = BackendProfile(
    name="gcs", latency_s=45e-3, conn_bps=200e6, parallelism=32,
    slow_start_ops=8, slow_start_factor=2.0)

NVME_PROFILE = BackendProfile(
    name="nvme", latency_s=120e-6, conn_bps=2.5e9, parallelism=16,
    capacity_bytes=256 << 20, durable=False)


class GcsStore(ObjectBackend):
    """GCS-like backend: fewer but faster connections than S3, higher
    per-request latency, and a connection slow-start ramp on the first
    transfers — a genuinely different lane and failure envelope."""

    profile_defaults = GCS_PROFILE

    def __init__(self, clock: SimClock,
                 profile: BackendProfile | None = None) -> None:
        super().__init__(clock, profile or self.profile_defaults)


class NvmeStore(ObjectBackend):
    """Local-NVMe cache tier: microsecond latency, node-class bandwidth,
    **bounded capacity** (`CosCapacityError` on overflow) and *not* durable
    in the tiering sense — `core/tiering.py` must land dirty bytes on a
    durable tier before this one may evict them."""

    profile_defaults = NVME_PROFILE

    def __init__(self, clock: SimClock,
                 profile: BackendProfile | None = None,
                 capacity_bytes: int | None = None) -> None:
        profile = profile or self.profile_defaults
        if capacity_bytes is not None:
            profile = replace(profile, capacity_bytes=capacity_bytes)
        super().__init__(clock, profile)

    def evict(self, bucket: str, key: str) -> int:
        """Drop a resident object without charging the lane (metadata-only
        invalidation); returns the bytes freed.  The tiering engine calls
        this only after the dirty-durability invariant is satisfied."""
        data = self._objects.pop((bucket, key), None)
        return len(data) if data is not None else 0
