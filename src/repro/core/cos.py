"""Simulated cloud object storage (S3-compatible surface).

Implements the API subset objcache needs (§5.2): PutObject, GetObject with
range reads, ListObjectsV2-style prefix+delimiter listing, DeleteObject, and
multipart upload (begin / add part / commit / abort).  Backed by an in-memory
dict of real bytes; timing charged against a shared `Resource` modelling a
regional bucket (per-request latency + per-connection bandwidth with bounded
parallelism).  Failure injection hooks let tests exercise the black-dot crash
points of Fig. 8.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from .simclock import HardwareModel, Resource, SimClock


class CosError(Exception):
    pass


@dataclass
class _MPU:
    bucket: str
    key: str
    upload_id: str
    parts: dict[int, bytes] = field(default_factory=dict)


class CosStore:
    """One external storage endpoint holding many buckets."""

    def __init__(self, clock: SimClock, hw: HardwareModel | None = None) -> None:
        self.clock = clock
        self.hw = hw or HardwareModel()
        self.resource: Resource = self.hw.make_cos()
        self._objects: dict[tuple[str, str], bytes] = {}
        self._mpus: dict[str, _MPU] = {}
        self._upload_ids = itertools.count(1)
        # failure injection: set of op names that fail once when next invoked
        self._fail_once: set[str] = set()
        # stats
        self.ops: dict[str, int] = {}
        self.bytes_in = 0
        self.bytes_out = 0

    # ---- failure injection -------------------------------------------------
    def fail_next(self, op: str) -> None:
        self._fail_once.add(op)

    def _maybe_fail(self, op: str) -> None:
        self.ops[op] = self.ops.get(op, 0) + 1
        if op in self._fail_once:
            self._fail_once.discard(op)
            raise CosError(f"injected failure: {op}")

    # ---- data plane ----------------------------------------------------------
    def make_bucket(self, bucket: str) -> None:
        # buckets are implicit; kept for API parity
        self._maybe_fail("make_bucket")

    def put_object(self, bucket: str, key: str, data: bytes,
                   start: float | None = None) -> float:
        self._maybe_fail("put_object")
        t0 = self.clock.now if start is None else start
        end = self.resource.acquire(t0, len(data))
        self._objects[(bucket, key)] = bytes(data)
        self.bytes_in += len(data)
        return end

    def get_object(self, bucket: str, key: str,
                   rng: tuple[int, int] | None = None,
                   start: float | None = None) -> tuple[bytes, float]:
        """rng = (offset, length) half-open byte range."""
        self._maybe_fail("get_object")
        obj = self._objects.get((bucket, key))
        if obj is None:
            raise CosError(f"NoSuchKey: s3://{bucket}/{key}")
        if rng is not None:
            off, ln = rng
            data = obj[off:off + ln]
        else:
            data = obj
        t0 = self.clock.now if start is None else start
        end = self.resource.acquire(t0, len(data))
        self.bytes_out += len(data)
        return data, end

    def head_object(self, bucket: str, key: str,
                    start: float | None = None) -> tuple[int, float]:
        self._maybe_fail("head_object")
        obj = self._objects.get((bucket, key))
        if obj is None:
            raise CosError(f"NoSuchKey: s3://{bucket}/{key}")
        t0 = self.clock.now if start is None else start
        return len(obj), self.resource.acquire(t0, 0)

    def exists(self, bucket: str, key: str) -> bool:
        return (bucket, key) in self._objects

    def list_prefix(self, bucket: str, prefix: str, delimiter: str = "/",
                    start: float | None = None
                    ) -> tuple[list[tuple[str, int]], list[str], float]:
        """Returns (objects=[(key,size)...], common_prefixes, t_end); COS has
        no directories — keys under `prefix` up to `delimiter` (§3.2, §5.4)."""
        self._maybe_fail("list_prefix")
        objs: list[tuple[str, int]] = []
        prefixes: set[str] = set()
        for (b, k), v in self._objects.items():
            if b != bucket or not k.startswith(prefix):
                continue
            rest = k[len(prefix):]
            if not rest:
                objs.append((k, len(v)))
                continue
            if delimiter and delimiter in rest:
                prefixes.add(prefix + rest.split(delimiter, 1)[0] + delimiter)
            else:
                objs.append((k, len(v)))
        t0 = self.clock.now if start is None else start
        end = self.resource.acquire(t0, 0)
        return sorted(objs), sorted(prefixes), end

    def delete_object(self, bucket: str, key: str,
                      start: float | None = None) -> float:
        self._maybe_fail("delete_object")
        self._objects.pop((bucket, key), None)  # S3 delete is idempotent
        t0 = self.clock.now if start is None else start
        return self.resource.acquire(t0, 0)

    # ---- multipart upload (§5.2) ---------------------------------------------
    def mpu_begin(self, bucket: str, key: str,
                  start: float | None = None) -> tuple[str, float]:
        self._maybe_fail("mpu_begin")
        uid = f"mpu-{next(self._upload_ids)}"
        self._mpus[uid] = _MPU(bucket, key, uid)
        t0 = self.clock.now if start is None else start
        return uid, self.resource.acquire(t0, 0)

    def mpu_add(self, upload_id: str, part_no: int, data: bytes,
                start: float | None = None) -> float:
        self._maybe_fail("mpu_add")
        mpu = self._mpus.get(upload_id)
        if mpu is None:
            raise CosError(f"NoSuchUpload: {upload_id}")
        mpu.parts[part_no] = bytes(data)
        self.bytes_in += len(data)
        t0 = self.clock.now if start is None else start
        return self.resource.acquire(t0, len(data))

    def mpu_commit(self, upload_id: str,
                   start: float | None = None) -> float:
        self._maybe_fail("mpu_commit")
        mpu = self._mpus.pop(upload_id, None)
        if mpu is None:
            raise CosError(f"NoSuchUpload: {upload_id}")
        blob = b"".join(mpu.parts[i] for i in sorted(mpu.parts))
        self._objects[(mpu.bucket, mpu.key)] = blob
        t0 = self.clock.now if start is None else start
        return self.resource.acquire(t0, 0)

    def mpu_abort(self, upload_id: str, start: float | None = None) -> float:
        self._maybe_fail("mpu_abort")
        self._mpus.pop(upload_id, None)  # idempotent
        t0 = self.clock.now if start is None else start
        return self.resource.acquire(t0, 0)

    def outstanding_mpus(self) -> list[str]:
        return sorted(self._mpus)
