"""Open-loop multi-tenant traffic generation over the sim clock.

Every benchmark before this module drove the cluster *closed-loop*: each
client issues its next operation only after the previous one returns, so a
slow server silently throttles its own offered load and queueing collapse
never shows up in the numbers (the paper's Figs. 9/13-14 and the old
16-client `multi_tenant.json` all have this blind spot).  This module
generates *open-loop* traffic: arrivals are scheduled by a stochastic
process up front, and every operation starts at its scheduled time whether
or not earlier operations have finished.  `SimClock.at` rewinds the shared
clock to each arrival; the `Resource` lanes keep their own ``free_at``
bookkeeping, so at overload the queueing delay compounds exactly as a real
open system's would and p999 diverges at the knee.

Pieces:

* arrival processes — `PoissonArrivals`, `OnOffArrivals` (bursty ON/OFF with
  exponential phase lengths), `TraceArrivals` (replay a recorded timeline);
* `TenantSpec` — per-tenant arrival process, virtual-client population, op
  mix, and Zipf popularity exponent;
* `build_schedule` — deterministic (seeded) merge of per-tenant event
  streams into a single time-ordered `Schedule`; serializable through
  `Schedule.to_payload` / `from_payload` (the trace format — a schedule can
  be saved, diffed, and replayed bit-for-bit);
* `OpenLoopRunner` — executes a schedule against a cluster through a bounded
  pool of real `ObjcacheFS` clients per tenant (thousands of *virtual*
  clients map onto the pool, like FUSE processes shared per node); shed
  operations (`AdmissionError` from the router's token buckets) are
  recorded, never retried — open-loop load does not self-throttle;
* `summarize` — p50/p99/p999 latency, goodput, shed rate, and Jain's
  fairness index per tenant and overall;
* `fs_fingerprint` — end-state digest (namespace + sizes + content hashes)
  for deterministic-replay and metamorphic tests.

Contracts the pieces rely on:

* **Determinism is structural, not incidental.**  Each tenant draws from
  its own ``default_rng([seed, tenant_index])`` substream, so adding or
  reordering tenants never perturbs another tenant's arrivals; clients
  take explicit ``client_id``s because the process-global counter's
  decimal width leaks into staged-part key strings → payload bytes →
  virtual transfer times.  Two clusters replaying the same schedule
  reach bit-identical fingerprints.
* **Arrival-charged admission clock.**  The runner calls
  `Router.note_arrival(tenant, t_arrival)` before dispatching each op so
  the router's GCRA bucket charges *every* envelope of the op at its
  scheduled arrival, not at its post-queueing dispatch time — otherwise
  backlog would mint refill credit and overload could never shed (the
  full argument is in `net.py`'s module docstring).  Anything replaying
  a schedule against a policed router must preserve this call.
* **Shed means shed.**  An `AdmissionError` is recorded and the op is
  never retried: open-loop load must not self-throttle, that being the
  blind spot this module exists to remove.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np

from .client import ClientConfig, ObjcacheClient
from .fs import ObjcacheFS
from .net import SimCrash, SimTimeout, TenantQos
from .simclock import HardwareModel
from .types import AdmissionError, FSError, InodeKind

OPS = ("stat", "listdir", "read", "write", "create")


# =========================================================================
# arrival processes
# =========================================================================
class ArrivalProcess:
    """Yields arrival offsets in [0, horizon) given a seeded generator."""

    def times(self, horizon_s: float, rng: np.random.Generator) -> list[float]:
        raise NotImplementedError


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at `rate_ops_s` (exponential inter-arrival)."""

    rate_ops_s: float

    def times(self, horizon_s: float, rng: np.random.Generator) -> list[float]:
        out: list[float] = []
        t = float(rng.exponential(1.0 / self.rate_ops_s))
        while t < horizon_s:
            out.append(t)
            t += float(rng.exponential(1.0 / self.rate_ops_s))
        return out


@dataclass(frozen=True)
class OnOffArrivals(ArrivalProcess):
    """Bursty ON/OFF source: Poisson at `on_rate_ops_s` during ON phases,
    silent during OFF; phase lengths are exponential with the given means.
    Mean rate = on_rate * mean_on / (mean_on + mean_off), but the bursts
    hit the fabric at the full ON rate — the tail-latency stressor."""

    on_rate_ops_s: float
    mean_on_s: float = 0.2
    mean_off_s: float = 0.3

    def times(self, horizon_s: float, rng: np.random.Generator) -> list[float]:
        out: list[float] = []
        t = 0.0
        on = True
        while t < horizon_s:
            phase = float(rng.exponential(
                self.mean_on_s if on else self.mean_off_s))
            if on:
                tt = t + float(rng.exponential(1.0 / self.on_rate_ops_s))
                while tt < min(t + phase, horizon_s):
                    out.append(tt)
                    tt += float(rng.exponential(1.0 / self.on_rate_ops_s))
            t += phase
            on = not on
        return out


@dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay a recorded arrival timeline (offsets from t=0)."""

    offsets: tuple[float, ...]

    def times(self, horizon_s: float, rng: np.random.Generator) -> list[float]:
        return [t for t in self.offsets if 0.0 <= t < horizon_s]


# =========================================================================
# tenants and schedules
# =========================================================================
@dataclass
class TenantSpec:
    """One tenant's traffic shape.  `n_clients` is the *virtual* client
    population (arrival attribution + per-client identity); the runner maps
    them onto a bounded pool of real clients.  `op_mix` weights over OPS;
    `zipf_s` is the popularity exponent over the shared file/dir catalog
    (1.0–1.3 is the heavy-tailed regime seen in production file traces)."""

    name: str
    arrivals: ArrivalProcess
    n_clients: int = 256
    op_mix: dict[str, float] = field(default_factory=lambda: {
        "stat": 0.40, "listdir": 0.10, "read": 0.30, "write": 0.15,
        "create": 0.05})
    zipf_s: float = 1.1
    write_bytes: int = 8192
    # QoS class carried into benchmark reports / admission policies; the
    # loadgen itself does not interpret it
    qos_class: str = "standard"


@dataclass(frozen=True)
class OpEvent:
    t: float        # absolute arrival time on the sim clock
    tenant: str
    vclient: int    # virtual client index within the tenant
    op: str         # one of OPS
    path: str
    size: int = 0   # payload bytes for write/create

    def to_row(self) -> list:
        # raw float: JSON round-trips doubles exactly, and the trace format
        # must replay bit-for-bit
        return [self.t, self.tenant, self.vclient, self.op,
                self.path, self.size]

    @staticmethod
    def from_row(row: list) -> "OpEvent":
        return OpEvent(t=float(row[0]), tenant=row[1], vclient=int(row[2]),
                       op=row[3], path=row[4], size=int(row[5]))


@dataclass
class Schedule:
    """A fully materialized open-loop trace: time-ordered events plus the
    provenance needed to reproduce it.  `to_payload`/`from_payload` is the
    trace format — plain JSON-compatible rows, replayable bit-for-bit."""

    horizon_s: float
    seed: int
    events: list[OpEvent]

    def offered(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for ev in self.events:
            out[ev.tenant] = out.get(ev.tenant, 0) + 1
        return out

    def to_payload(self) -> dict:
        return {"horizon_s": self.horizon_s, "seed": self.seed,
                "events": [ev.to_row() for ev in self.events]}

    @staticmethod
    def from_payload(p: dict) -> "Schedule":
        return Schedule(horizon_s=float(p["horizon_s"]), seed=int(p["seed"]),
                        events=[OpEvent.from_row(r) for r in p["events"]])


def zipf_weights(n: int, s: float) -> np.ndarray:
    w = 1.0 / np.arange(1, n + 1, dtype=float) ** s
    return w / w.sum()


def build_schedule(tenants: list[TenantSpec], files: list[str],
                   dirs: list[str], horizon_s: float, seed: int) -> Schedule:
    """Deterministic schedule: same (tenants, catalog, horizon, seed) ⇒
    identical event list.  Each tenant draws from its own `(seed, index)`
    substream, so adding a tenant never perturbs the others' traffic.
    Create targets land under `/bench/<tenant>/` (pre-created by the
    caller); everything else draws Zipf-popular paths from the catalog."""
    assert files and dirs, "catalog must be populated before scheduling"
    events: list[tuple[float, int, int, OpEvent]] = []
    for ti, spec in enumerate(tenants):
        rng = np.random.default_rng([seed, ti])
        times = spec.arrivals.times(horizon_s, rng)
        names = [op for op in OPS if spec.op_mix.get(op, 0.0) > 0.0]
        probs = np.array([spec.op_mix[op] for op in names], dtype=float)
        probs /= probs.sum()
        wf = zipf_weights(len(files), spec.zipf_s)
        wd = zipf_weights(len(dirs), spec.zipf_s)
        # popularity rank -> catalog index: a tenant-specific permutation so
        # tenants do not all hammer the same head-of-catalog files
        pf = rng.permutation(len(files))
        pd = rng.permutation(len(dirs))
        created = 0
        for k, t in enumerate(times):
            vclient = int(rng.integers(spec.n_clients))
            op = names[int(rng.choice(len(names), p=probs))]
            size = 0
            if op == "listdir":
                path = dirs[pd[int(rng.choice(len(dirs), p=wd))]]
            elif op == "create":
                path = f"/bench/{spec.name}/c{created}.bin"
                created += 1
                size = spec.write_bytes
            else:
                path = files[pf[int(rng.choice(len(files), p=wf))]]
                if op == "write":
                    size = spec.write_bytes
            events.append((t, ti, k, OpEvent(t=t, tenant=spec.name,
                                             vclient=vclient, op=op,
                                             path=path, size=size)))
    events.sort(key=lambda e: (e[0], e[1], e[2]))
    return Schedule(horizon_s=horizon_s, seed=seed,
                    events=[e[3] for e in events])


# =========================================================================
# execution
# =========================================================================
@dataclass
class OpResult:
    ev: OpEvent
    status: str          # "ok" | "shed" | "err"
    latency_s: float
    errno: int = 0


class OpenLoopRunner:
    """Executes a `Schedule` against a cluster.  Each tenant gets a bounded
    pool of real clients spread round-robin across the nodes; virtual client
    `v` uses pool slot `v % pool`.  Operations run at their scheduled
    arrival time (`SimClock.at`), and per-op latency is completion minus
    arrival — including any admission delay and resource queueing."""

    def __init__(self, cluster, tenants: list[TenantSpec], *,
                 consistency: str = "strict", pool_per_tenant: int = 8,
                 deployment: str = "detached") -> None:
        self.cluster = cluster
        self.clock = cluster.clock
        self.pools: dict[str, list[ObjcacheFS]] = {}
        nodes = cluster.node_list()
        # deterministic client ids, allocated from a per-cluster counter:
        # the process-global id counter would leak each run's position in
        # the process into staged-part key widths and hence virtual timing,
        # breaking same-seed reproducibility across clusters
        cid = getattr(cluster, "_loadgen_next_cid", 10_000)
        for spec in tenants:
            pool = []
            for i in range(min(pool_per_tenant, max(1, spec.n_clients))):
                client = ObjcacheClient(
                    cluster.router, cluster.clock, nodes[i % len(nodes)],
                    ClientConfig(consistency=consistency,
                                 deployment=deployment, tenant=spec.name),
                    chunk_size=cluster.cfg.chunk_size, client_id=cid)
                cid += 1
                pool.append(ObjcacheFS(client))
            self.pools[spec.name] = pool
        cluster._loadgen_next_cid = cid

    def fs_for(self, tenant: str, vclient: int) -> ObjcacheFS:
        pool = self.pools[tenant]
        return pool[vclient % len(pool)]

    def _exec(self, fs: ObjcacheFS, ev: OpEvent) -> None:
        if ev.op == "stat":
            fs.stat(ev.path)
        elif ev.op == "listdir":
            fs.listdir(ev.path)
        elif ev.op == "read":
            fs.read_file(ev.path)
        elif ev.op in ("write", "create"):
            fs.write_file(ev.path, bytes(ev.size))
        else:  # pragma: no cover
            raise ValueError(f"unknown op {ev.op!r}")

    def run(self, schedule: Schedule, *,
            base_t: float | None = None) -> list[OpResult]:
        # Rebase the schedule's t=0 onto the clock at run start: catalog
        # bootstrap has already consumed virtual time and resource lanes, and
        # without the offset every op would inherit that backlog as latency.
        t0 = self.clock.now if base_t is None else base_t
        router = self.cluster.router
        results: list[OpResult] = []
        for ev in schedule.events:
            self.clock.at(t0 + ev.t)
            # charge all of this op's envelopes at its arrival: dispatch
            # times include queueing straggle, which must not refill (or
            # penalize) the tenant's token bucket
            router.note_arrival(ev.tenant, t0 + ev.t)
            status, errno = "ok", 0
            try:
                self._exec(self.fs_for(ev.tenant, ev.vclient), ev)
            except AdmissionError:
                status = "shed"
            except FSError as e:
                status, errno = "err", int(e.errno)
            except (SimTimeout, SimCrash):
                status = "err"
            results.append(OpResult(ev=ev, status=status,
                                    latency_s=self.clock.now - (t0 + ev.t),
                                    errno=errno))
        return results


# =========================================================================
# reporting
# =========================================================================
def jain_index(xs: list[float]) -> float:
    """Jain's fairness index over per-tenant allocations: 1.0 = perfectly
    fair, 1/n = one tenant takes everything."""
    xs = [x for x in xs if x == x]
    if not xs or all(x == 0 for x in xs):
        return 1.0
    s, sq = sum(xs), sum(x * x for x in xs)
    return (s * s) / (len(xs) * sq) if sq else 1.0


def _pctl(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, dtype=float), q)) if xs else 0.0


def _cell(rs: list[OpResult], horizon_s: float) -> dict:
    lats = [r.latency_s for r in rs if r.status == "ok"]
    ok = len(lats)
    shed = sum(1 for r in rs if r.status == "shed")
    err = len(rs) - ok - shed
    return {
        "offered": len(rs),
        "offered_ops_s": round(len(rs) / horizon_s, 1),
        "ok": ok, "shed": shed, "err": err,
        "goodput_ops_s": round(ok / horizon_s, 1),
        "shed_rate": round(shed / max(1, len(rs)), 4),
        "p50_ms": round(_pctl(lats, 50) * 1e3, 4),
        "p99_ms": round(_pctl(lats, 99) * 1e3, 4),
        "p999_ms": round(_pctl(lats, 99.9) * 1e3, 4),
        "mean_ms": round(float(np.mean(lats)) * 1e3, 4) if lats else 0.0,
        "max_ms": round(max(lats) * 1e3, 4) if lats else 0.0,
    }


def summarize(results: list[OpResult], horizon_s: float) -> dict:
    """Aggregate an open-loop run: overall + per-tenant latency percentiles,
    goodput, shed rate, and Jain fairness over per-tenant served fractions
    (goodput / offered — equal degradation scores 1.0, starvation of one
    tenant pulls the index toward 1/n)."""
    by_tenant: dict[str, list[OpResult]] = {}
    for r in results:
        by_tenant.setdefault(r.ev.tenant, []).append(r)
    tenants = {name: _cell(rs, horizon_s)
               for name, rs in sorted(by_tenant.items())}
    served = [c["ok"] / max(1, c["offered"]) for c in tenants.values()]
    return {"overall": _cell(results, horizon_s), "tenants": tenants,
            "jain_fairness": round(jain_index(served), 4)}


# =========================================================================
# end-state fingerprinting (deterministic-replay / metamorphic tests)
# =========================================================================
def fs_fingerprint(fs: ObjcacheFS, root: str = "/") -> dict[str, tuple]:
    """Deterministic digest of the namespace under `root`: directories map
    to their sorted child names, files to (size, sha1 of content).  Excludes
    mtimes/versions on purpose — two runs of the same trace through
    different fast-path configurations commit at different virtual times but
    must converge to the same *state*.  Reads go through the client, so
    callers should clear any admission policy first."""
    out: dict[str, tuple] = {}
    stack = [root.rstrip("/") or "/"]
    while stack:
        cur = stack.pop()
        names = fs.listdir(cur)
        out[cur] = ("dir", tuple(names))
        for name in names:
            child = (cur.rstrip("/") + "/" + name)
            st = fs.stat(child)
            if st["kind"] == int(InodeKind.DIR):
                stack.append(child)
            else:
                data = fs.read_file(child)
                out[child] = ("file", st["size"],
                              hashlib.sha1(data).hexdigest())
    return out


# =========================================================================
# scaled hardware for load tests
# =========================================================================
def loadtest_hw() -> HardwareModel:
    """Scaled-down hardware for open-loop load tests: few lanes and
    millisecond-scale service times so the queueing knee appears at O(1e3)
    ops/s with O(1e4) events — the same wall-time-driven scaling as the
    workload constants in `benchmarks/common.py` (the reports read *ratios*,
    not absolutes).  COS keeps its real latency class."""
    return HardwareModel(
        disk_write_bps=200e6, disk_read_bps=300e6, disk_latency_s=2e-3,
        disk_parallelism=2,
        nic_bps=1.25e9, net_rtt_s=2e-4, nic_parallelism=4,
        loopback_bps=600e6, loopback_rtt_s=1e-4,
        mem_bps=12.0e9,
        cos_latency_s=30e-3, cos_conn_bps=120e6, cos_parallelism=16)


def default_qos_policy(capacity_ops_s: float, env_per_op: float = 4.7
                       ) -> dict[str, TenantQos]:
    """A reference three-class policy over an estimated cluster capacity (in
    filesystem ops/s) and an average envelope cost per op (~4.7 for the
    mixed stat/list/read/write workload on strict clients): `gold` is
    contracted *above* its expected share so it is never policed at 2x
    overload, `silver` gets a fair share with burst headroom for its ON/OFF
    spikes, `best` is clipped hard so its overload cannot starve the paying
    classes.  Shares deliberately sum past 1.0 — classic statistical
    multiplexing; the bucket rates bound each class's worst case, not the
    steady-state sum."""
    env = capacity_ops_s * env_per_op
    return {
        "gold": TenantQos(rate_ops_s=0.75 * env, burst=64, queue_depth=64),
        "silver": TenantQos(rate_ops_s=0.25 * env, burst=48, queue_depth=48),
        "best": TenantQos(rate_ops_s=0.20 * env, burst=24, queue_depth=16),
    }
