"""Ring-change migration: scan / send / receive (§4.3, §5.5).

`Migrator` implements the data movement of a reconfiguration, driven by the
`Cluster` operator: scan for objects whose owner changes under the new ring,
push dirty objects (and *all* directories — the grandparent-overwrite
hazard, §4.3) to their new owners, and evict what moved or can be refetched
from COS.  The receive side logs MIGRATE_RECV_* records so a crashed
receiver replays to the same state.
"""

from __future__ import annotations

from .hashring import HashRing
from .net import rpc_handler
from .participant import Participant
from .simclock import InflightWindow
from .state import ServerState
from .stores import ChunkState, Segment
from .types import Cmd, InodeKind, InodeMeta, chunk_key, meta_key


class Migrator:
    def __init__(self, state: ServerState, wal: Participant) -> None:
        self.state = state
        self.wal = wal

    @rpc_handler()
    def rpc_set_read_only(self, start: float, value: bool
                          ) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        st.read_only = value
        return {"ok": True}, start

    def migration_scan(self, new_ring: HashRing) -> dict:
        """Objects this node owns whose owner changes under `new_ring`.
        Policy (§4.3/§5.5): dirty metadata + dirty chunks migrate; directories
        *always* migrate (the grandparent-overwrite hazard); clean files are
        dropped (refetchable from COS)."""
        st = self.state
        out = {"metas": [], "dirs": [], "chunks": [], "drop_metas": [],
               "drop_chunks": []}
        for ino, m in st.metas.inodes.items():
            if st.ring.node_for(meta_key(ino)) != st.node_id:
                continue  # not ours (stale leftover)
            new_owner = new_ring.node_for(meta_key(ino))
            if new_owner == st.node_id:
                continue
            if m.kind == InodeKind.DIR:
                out["dirs"].append((ino, new_owner))
            elif m.dirty:
                out["metas"].append((ino, new_owner))
            else:
                out["drop_metas"].append(ino)
        for (ino, coff), c in st.chunks.chunks.items():
            if st.ring.node_for(chunk_key(ino, coff)) != st.node_id:
                continue
            new_owner = new_ring.node_for(chunk_key(ino, coff))
            if new_owner == st.node_id:
                continue
            if c.dirty:
                out["chunks"].append(((ino, coff), new_owner))
            else:
                out["drop_chunks"].append((ino, coff))
        return out

    def migrate_out(self, scan: dict, start: float) -> tuple[dict, float]:
        """Push scanned objects to their new owners; evict moved + dropped.

        Sends are pipelined: batched per destination and dispatched through
        a bounded in-flight window so transfers to different receivers (and
        multiple chunks to the same receiver, up to its NIC lanes) overlap
        on the network resource instead of serializing in virtual time.
        Each source-side EVICT is logged at its send's completion, so replay
        still reconstructs the same end state."""
        st = self.state
        moved = {"metas": 0, "dirs": 0, "chunks": 0, "bytes": 0}
        window = InflightWindow(st.cfg.migrate_inflight)
        ends: list[float] = []

        # batch per destination: all of one receiver's metas/chunks are
        # enqueued adjacently so its NIC lanes stay saturated
        metas_by_dst: dict[str, list[int]] = {}
        for ino, dst in scan["dirs"] + scan["metas"]:
            metas_by_dst.setdefault(dst, []).append(ino)
        chunks_by_dst: dict[str, list[tuple[int, int]]] = {}
        for key, dst in scan["chunks"]:
            chunks_by_dst.setdefault(dst, []).append(key)

        for dst in sorted(metas_by_dst):
            # metadata handoffs are small control records: all of one
            # receiver's metas/dirs coalesce into a single batched envelope
            # (O(destinations) messages), falling back to one RPC each when
            # batching is disabled
            calls, kinds = [], []
            for ino in metas_by_dst[dst]:
                m = st.metas.get(ino)
                if m is None:
                    continue
                is_dir = m.kind == InodeKind.DIR
                calls.append({"method": "rpc_migrate_recv_meta",
                              "kwargs": {"meta": m.to_payload(),
                                         "is_dir": is_dir},
                              "nbytes_out": len(str(m.to_payload())) + 64})
                kinds.append((ino, is_dir))
            if not calls:
                continue
            if st.cfg.batch_rpcs:
                begin = window.admit(start)
                _, te = st.router.rpc_batch(st.node_id, dst, calls, begin)
                for ino, is_dir in kinds:
                    te = self.wal.log(Cmd.EVICT_META, {"ino": ino}, te)
                    moved["dirs" if is_dir else "metas"] += 1
                window.settle(te)
                ends.append(te)
            else:
                for call, (ino, is_dir) in zip(calls, kinds):
                    begin = window.admit(start)
                    _, te = st.router.rpc(
                        st.node_id, dst, call["method"], begin,
                        nbytes_out=call["nbytes_out"], **call["kwargs"])
                    te = self.wal.log(Cmd.EVICT_META, {"ino": ino}, te)
                    window.settle(te)
                    ends.append(te)
                    moved["dirs" if is_dir else "metas"] += 1
        for dst in sorted(chunks_by_dst):
            for ino, coff in chunks_by_dst[dst]:
                c = st.chunks.get(ino, coff)
                if c is None:
                    continue
                data = c.materialize(st.raft, max(s.off + s.length for s in
                                                  c.base_filled + c.segments)) \
                    if (c.base_filled or c.segments) else b""
                begin = window.admit(start)
                _, te = st.router.rpc(
                    st.node_id, dst, "rpc_migrate_recv_chunk", begin,
                    nbytes_out=len(data) + 128,
                    ino=ino, chunk_off=coff, version=c.version, dirty=c.dirty,
                    deleted=c.deleted, data=data)
                te = self.wal.log(Cmd.EVICT_CHUNK,
                                  {"ino": ino, "chunk_off": coff}, te)
                window.settle(te)
                ends.append(te)
                moved["chunks"] += 1
                moved["bytes"] += len(data)
        t = max(ends) if ends else start
        for ino in scan["drop_metas"]:
            t = self.wal.log(Cmd.EVICT_META, {"ino": ino}, t)
        for (ino, coff) in scan["drop_chunks"]:
            t = self.wal.log(Cmd.EVICT_CHUNK, {"ino": ino, "chunk_off": coff},
                             t)
        return moved, t

    @rpc_handler(request_bytes=512)
    def rpc_migrate_recv_meta(self, start: float, meta: dict, is_dir: bool
                              ) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        existing = st.metas.get(meta["ino"])
        if existing is not None and existing.kind == InodeKind.DIR and is_dir:
            # merge children: never overwrite a newer dir with an older copy
            # (§4.3 grandparent-overwrite hazard)
            merged = InodeMeta.from_payload(meta)
            merged.children.update(existing.children)
            merged.version = max(merged.version, existing.version)
            meta = merged.to_payload()
        cmd = Cmd.MIGRATE_RECV_DIR if is_dir else Cmd.MIGRATE_RECV_META
        t = self.wal.log(cmd, {"meta": meta}, start)
        return {"ok": True}, t

    @rpc_handler(request_bytes=512)
    def rpc_migrate_recv_chunk(self, start: float, ino: int, chunk_off: int,
                               version: int, dirty: bool, deleted: bool,
                               data: bytes) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        ref, t = st.raft.append_bulk(bytes(data), start=start)
        chunk = ChunkState(ino=ino, chunk_off=chunk_off, version=version,
                           dirty=dirty, deleted=deleted,
                           segments=[Segment(0, len(data), ref)])
        t = self.wal.log(Cmd.MIGRATE_RECV_CHUNK, {"chunk": chunk.to_payload()},
                         t)
        return {"ok": True}, t
