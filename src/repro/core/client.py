"""Node-local cache client — the paper's FUSE-process role (§3.2–3.3, §5).

One `ObjcacheClient` runs on a node (colocated with that node's cache server)
and implements the node-local cache tier:

* **consistency models** (§3.3): `strict` (read-after-write) disables client
  buffering and the page cache — every write commits to cluster-local cache
  before returning, every read consults the cluster; `weak` (close-to-open)
  buffers writes up to 128 KB (the Linux-FUSE limit the paper observed),
  keeps a node-local page cache of chunks, and validates once at open().
* **deployment models** (§3.1): `embedded` colocates client and server in one
  process (no hop to the local server); `detached` pays a loopback hop.
* **node-list versioning** (§4.3): every request carries the client's copy of
  the node-list version; ESTALE answers trigger a pull + retry.
* **TxId discipline** (§4.5): one SeqNum per file operation, *reused on
  retries*, so coordinator/participant dedup makes retries idempotent.

The client computes object placement itself with the same consistent-hash
ring the servers use, and sends each operation to the metadata owner as the
transaction coordinator (§4.4).
"""

from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field

from .hashring import HashRing
from .net import Router, SimCrash, SimTimeout
from .simclock import SimClock
from .types import (Errno, FSError, InodeKind, ROOT_INODE, StaleLeaseError,
                    chunk_key, meta_key)

_client_ids = itertools.count(1)


@dataclass
class ClientConfig:
    consistency: str = "weak"          # "strict" | "weak"  (§3.3)
    deployment: str = "detached"       # "detached" | "embedded"  (§3.1)
    # QoS tenant tag carried on every data/metadata envelope this client
    # sends; None = untagged (never policed).  Control-plane traffic (the
    # node-list pull) stays untagged so a shed tenant can still re-route.
    tenant: str | None = None
    page_cache_bytes: int = 1 << 30
    write_buffer_bytes: int = 128 * 1024   # §6.2: Linux allowed up to 128 KB
    readahead_chunks: int = 4          # chunks prefetched ahead on seq reads
    max_retries: int = 4
    # deterministic bounded exponential backoff on ECONFLICT retries
    # (base * 2^attempt, capped); a "queued" verdict means this TxId kept
    # its place in the owner's wait-die queue, so it comes back after just
    # the base delay to claim the lock hand-off reservation
    backoff_base_s: float = 0.0005
    backoff_cap_s: float = 0.016


@dataclass
class _Handle:
    fh: int
    ino: int
    path: str
    writable: bool
    # weak-mode write buffer: list of (off, bytes), coalesced at flush
    buffer: list[tuple[int, bytes]] = field(default_factory=list)
    buffered_bytes: int = 0
    # handle-local stream cache for strict mode: {chunk_off: (bytes,
    # ready_t, meta_version)} — strict reads getattr() first, so entries are
    # only served when the inode version is unchanged (read-after-write)
    stream_cache: dict[int, tuple[bytes, float, int]] = \
        field(default_factory=dict)
    last_read_end: int = -1
    size_hint: int = 0
    appending_new: bool = False    # created this open; size grows monotonically


class ObjcacheClient:
    def __init__(self, router: Router, clock: SimClock, local_node: str,
                 cfg: ClientConfig | None = None,
                 chunk_size: int = 16 * 1024 * 1024,
                 client_id: int | None = None) -> None:
        self.router = router
        self.clock = clock
        self.local_node = local_node
        self.cfg = cfg or ClientConfig()
        self.chunk_size = chunk_size
        # explicit ids let reproducibility-sensitive callers (the open-loop
        # runner) avoid the process-global counter: the id's decimal width
        # leaks into staged-part keys and therefore payload bytes / timing
        self.client_id = next(_client_ids) if client_id is None else client_id
        self._seq = 0
        self.node_list: list[str] = []
        self.nl_version = 0
        self.ring = HashRing()
        self._fh = itertools.count(3)
        self.handles: dict[int, _Handle] = {}
        # node-local page cache: (ino, chunk_off) -> (bytes, ready_t, version)
        self._pages: OrderedDict[tuple[int, int], tuple[bytes, float, int]] = \
            OrderedDict()
        self._pages_bytes = 0
        # dentry cache (weak mode only): (parent, name) -> ino
        self._dentries: dict[tuple[int, str], int] = {}
        # attr cache (weak mode, validated at open): ino -> meta payload
        self._attrs: dict[int, dict] = {}
        # client leases (weak mode): ino -> {epoch, expires, owner, attrs,
        # children, loaded}.  A live lease answers repeat lookups/readdirs/
        # getattrs locally with zero RPCs; renewals carry the epoch so any
        # committed mutation at the owner invalidates the lease (ESTALE)
        self._leases: dict[int, dict] = {}
        self.stats: dict[str, float] = {}
        self._pull_node_list()

    # =====================================================================
    # plumbing
    # =====================================================================
    def _bump(self, k: str, n: float = 1) -> None:
        self.stats[k] = self.stats.get(k, 0) + n

    def next_seq(self) -> int:
        self._seq += 1
        return self._seq

    def _is_embedded(self, dst: str) -> bool:
        return self.cfg.deployment == "embedded" and dst == self.local_node

    def _pull_node_list(self) -> None:
        for dst in (list(self.ring.nodes()) or list(self.router.servers)):
            try:
                res, t = self.router.rpc(self.local_node, dst, "rpc_nodelist",
                                         self.clock.now,
                                         embedded_local=self._is_embedded(dst))
                self.clock.advance_to(t)
                self.node_list = res["nodes"]
                self.nl_version = res["version"]
                self.ring = HashRing(self.node_list)
                return
            except (SimTimeout, SimCrash):
                continue
        raise FSError(Errno.ETIMEDOUT, "no reachable server for node list")

    def _rpc(self, dst: str, method: str, *, nbytes_out: int | None = None,
             nbytes_in: int | None = None, **kw):
        """RPC with ESTALE pull-and-retry and timeout retries (same TxId).
        Payload sizes default to the handler's declared RpcSpec."""
        last: Exception | None = None
        for attempt in range(self.cfg.max_retries):
            try:
                res, t = self.router.rpc(
                    self.local_node, dst, method, self.clock.now,
                    nbytes_out=nbytes_out, nbytes_in=nbytes_in,
                    embedded_local=self._is_embedded(dst),
                    tenant=self.cfg.tenant, **kw)
                self.clock.advance_to(t)
                return res
            except StaleLeaseError as e:
                # a mutation committed since our grant: drop the cached copy
                # and re-fetch without the epoch (no node-list pull needed —
                # the owner is alive and correct, only our lease is stale)
                self._lease_drop(e.ino)
                if "lease_epoch" in kw:
                    kw["lease_epoch"] = None
                self._bump("lease_stale")
                last = e
                continue
            except FSError as e:
                if e.errno == Errno.ESTALE:
                    self._pull_node_list()
                    if "nl_version" in kw:
                        kw["nl_version"] = self.nl_version
                    dst = self._redirect(dst, method, kw)
                    last = e
                    continue
                if e.errno == Errno.ECONFLICT:
                    # racy lock conflict: bounded exponential backoff, then
                    # retry with the same TxId (dedup keeps it idempotent)
                    self._bump("conflict_retries")
                    self.clock.sleep(
                        self._backoff(attempt, getattr(e, "why", None)))
                    last = e
                    continue
                raise
            except (SimTimeout, SimCrash) as e:
                self.clock.sleep(self.router.timeout_s)
                self._pull_node_list()
                dst = self._redirect(dst, method, kw)
                last = e
        if isinstance(last, FSError):
            raise last
        raise FSError(Errno.ETIMEDOUT, f"{method} to {dst}: retries exhausted")

    def _redirect(self, dst: str, method: str, kw: dict) -> str:
        """After a node-list change, recompute the destination owner."""
        if "ino" in kw:
            return self.ring.node_for(meta_key(kw["ino"]))
        if "parent" in kw:
            return self.ring.node_for(meta_key(kw["parent"]))
        if dst in self.ring.nodes():
            return dst
        return self.ring.nodes()[0]

    def _backoff(self, attempt: int, why: str | None = None) -> float:
        if why == "queued":
            # we kept our place in the wait-die queue: the released lock is
            # reserved for this TxId, so come back after just the base delay
            return self.cfg.backoff_base_s
        return min(self.cfg.backoff_cap_s,
                   self.cfg.backoff_base_s * (2 ** attempt))

    # =====================================================================
    # client leases (metadata fast path; weak mode only)
    # =====================================================================
    def _lease_for(self, ino: int) -> dict | None:
        """The live lease on `ino`, or None.  A lease stops serving at its
        TTL expiry but the entry (and its epoch) is kept so the next fetch
        is a *renewal* the owner can validate; an ownership change drops the
        entry outright (epochs on different owners are not comparable)."""
        ent = self._leases.get(ino)
        if ent is None:
            return None
        if ent["owner"] != self.ring.node_for(meta_key(ino)):
            del self._leases[ino]
            return None
        if self.clock.now >= ent["expires"]:
            return None
        return ent

    def _lease_drop(self, ino: int) -> None:
        self._leases.pop(ino, None)

    def _lease_absorb(self, ino: int, grant: dict | None, *,
                      attrs: dict | None = None,
                      children: dict | None = None,
                      loaded: bool | None = None) -> None:
        """Record a lease grant from a reply (plus whatever cacheable content
        the reply carried).  A grant with a different epoch or owner starts a
        fresh entry — content cached under the old epoch is discarded."""
        if grant is None or self.cfg.consistency != "weak":
            return
        owner = self.ring.node_for(meta_key(ino))
        ent = self._leases.get(ino)
        if ent is None or ent["epoch"] != grant["epoch"] \
                or ent["owner"] != owner:
            ent = {"epoch": grant["epoch"], "owner": owner, "attrs": None,
                   "children": None, "loaded": None}
            self._leases[ino] = ent
        ent["expires"] = self.clock.now + grant["ttl"]
        if attrs is not None:
            ent["attrs"] = attrs
        if children is not None:
            ent["children"] = children
        if loaded is not None:
            ent["loaded"] = loaded

    def _lease_epoch_kw(self, ino: int) -> int | None:
        ent = self._leases.get(ino)
        return ent["epoch"] if ent is not None else None

    # =====================================================================
    # page cache (weak mode node-local tier)
    # =====================================================================
    def _page_get(self, ino: int, coff: int, version: int | None
                  ) -> tuple[bytes, float] | None:
        """Returns (data, ready_t).  NO clock side effects: in-flight
        readahead entries must not stall a read that does not need them —
        the caller charges ready_t only for chunks it returns."""
        ent = self._pages.get((ino, coff))
        if ent is None:
            return None
        data, ready_t, ver = ent
        if version is not None and ver != version:
            return None
        self._pages.move_to_end((ino, coff))
        self._bump("page_hits")
        return data, ready_t

    def _page_put(self, ino: int, coff: int, data: bytes, ready_t: float,
                  version: int) -> None:
        key = (ino, coff)
        old = self._pages.pop(key, None)
        if old is not None:
            self._pages_bytes -= len(old[0])
        self._pages[key] = (data, ready_t, version)
        self._pages_bytes += len(data)
        while self._pages_bytes > self.cfg.page_cache_bytes and self._pages:
            _, (d, _, _) = self._pages.popitem(last=False)
            self._pages_bytes -= len(d)

    def invalidate_ino(self, ino: int) -> None:
        for key in [k for k in self._pages if k[0] == ino]:
            d, _, _ = self._pages.pop(key)
            self._pages_bytes -= len(d)
        self._attrs.pop(ino, None)
        self._lease_drop(ino)

    # =====================================================================
    # namespace operations
    # =====================================================================
    def getattr(self, ino: int, *, cached_ok: bool = False) -> dict:
        weak = self.cfg.consistency == "weak"
        if cached_ok and weak:
            lease = self._lease_for(ino)
            if lease is not None and lease["attrs"] is not None:
                self._bump("lease_attr_hits")
                return lease["attrs"]
            if ino in self._attrs:
                self._bump("attr_hits")
                return self._attrs[ino]
        owner = self.ring.node_for(meta_key(ino))
        # carry the lease epoch as a renewal: an unchanged epoch confirms our
        # cached pages for this inode; a bumped one raises StaleLeaseError
        # and _rpc re-fetches fresh (close-to-open preserved at open())
        res = self._rpc(owner, "rpc_getattr", ino=ino,
                        nl_version=self.nl_version,
                        lease_epoch=self._lease_epoch_kw(ino) if weak
                        else None)
        grant = res.pop("lease", None)
        self._lease_absorb(ino, grant, attrs=res)
        if weak:
            self._attrs[ino] = res
        return res

    def lookup(self, parent: int, name: str) -> int:
        weak = self.cfg.consistency == "weak"
        if weak:
            lease = self._lease_for(parent)
            if lease is not None and lease["children"] is not None \
                    and lease["loaded"]:
                # zero-RPC fast path: the leased children map answers both
                # positive and negative lookups until the lease dies
                self._bump("lease_lookup_hits")
                child = lease["children"].get(name)
                if child is None:
                    raise FSError(Errno.ENOENT, f"{parent}/{name}")
                return child
            hit = self._dentries.get((parent, name))
            if hit is not None:
                return hit
        owner = self.ring.node_for(meta_key(parent))
        lease_kw = self._lease_epoch_kw(parent) if weak else None
        try:
            res = self._rpc(owner, "rpc_lookup", parent=parent, name=name,
                            nl_version=self.nl_version, lease_epoch=lease_kw)
        except FSError as e:
            if e.errno != Errno.ENOENT:
                raise
            # §3.2: retrieve the namespace lazily from external storage
            loaded = self._ensure_dir_loaded(parent)
            if not loaded:
                raise
            res = self._rpc(owner, "rpc_lookup", parent=parent, name=name,
                            nl_version=self.nl_version,
                            lease_epoch=self._lease_epoch_kw(parent) if weak
                            else None)
        ino = res["ino"]
        self._lease_absorb(parent, res.get("lease"))
        if weak:
            self._dentries[(parent, name)] = ino
        return ino

    def _ensure_dir_loaded(self, ino: int) -> bool:
        """Returns True if a COS listing was (or had been) applied."""
        weak = self.cfg.consistency == "weak"
        if weak:
            lease = self._lease_for(ino)
            if lease is not None and lease["loaded"]:
                return True     # zero-RPC: leased dir is known loaded
        owner = self.ring.node_for(meta_key(ino))
        res = self._rpc(owner, "rpc_readdir", ino=ino,
                        nl_version=self.nl_version,
                        lease_epoch=self._lease_epoch_kw(ino) if weak
                        else None)
        self._lease_absorb(ino, res.get("lease"),
                           children=res["children"], loaded=res["loaded"])
        if res["loaded"]:
            return True
        self._rpc(owner, "coord_load_dir", ino=ino,
                  client_id=self.client_id, seq=self.next_seq(),
                  nl_version=self.nl_version)
        # the load mutated the dir (children set, epoch bumped): our lease
        # content is stale by construction, refetch on next use
        self._lease_drop(ino)
        self._bump("dir_loads")
        return True

    def readdir(self, ino: int) -> dict[str, int]:
        weak = self.cfg.consistency == "weak"
        if weak:
            lease = self._lease_for(ino)
            if lease is not None and lease["children"] is not None \
                    and lease["loaded"]:
                self._bump("lease_readdir_hits")
                return dict(lease["children"])
        self._ensure_dir_loaded(ino)
        lease = self._lease_for(ino) if weak else None
        if lease is not None and lease["children"] is not None \
                and lease["loaded"]:
            # _ensure_dir_loaded just refreshed the lease: no second RPC
            self._bump("lease_readdir_hits")
            return dict(lease["children"])
        owner = self.ring.node_for(meta_key(ino))
        res = self._rpc(owner, "rpc_readdir", ino=ino,
                        nl_version=self.nl_version,
                        lease_epoch=self._lease_epoch_kw(ino) if weak
                        else None)
        self._lease_absorb(ino, res.get("lease"),
                           children=res["children"], loaded=res["loaded"])
        return res["children"]

    def create(self, parent: int, name: str, kind: InodeKind,
               cos_bucket: str | None, cos_key: str | None) -> int:
        owner = self.ring.node_for(meta_key(parent))
        res = self._rpc(owner, "coord_create", client_id=self.client_id,
                        seq=self.next_seq(), parent=parent, name=name,
                        kind=int(kind), cos_bucket=cos_bucket,
                        cos_key=cos_key, mtime=self.clock.now,
                        nl_version=self.nl_version)
        self._lease_drop(parent)   # our own mutation bumped the epoch
        if self.cfg.consistency == "weak":
            self._dentries[(parent, name)] = res["ino"]
        return res["ino"]

    def unlink(self, parent: int, name: str, ino: int) -> None:
        owner = self.ring.node_for(meta_key(ino))
        self._rpc(owner, "coord_unlink", client_id=self.client_id,
                  seq=self.next_seq(), parent=parent, name=name, ino=ino,
                  nl_version=self.nl_version)
        self._dentries.pop((parent, name), None)
        self._lease_drop(parent)
        self.invalidate_ino(ino)

    def rename(self, src_parent: int, src_name: str, dst_parent: int,
               dst_name: str, ino: int, new_cos_key: str | None) -> None:
        owner = self.ring.node_for(meta_key(ino))
        self._rpc(owner, "coord_rename", client_id=self.client_id,
                  seq=self.next_seq(), src_parent=src_parent,
                  src_name=src_name, dst_parent=dst_parent,
                  dst_name=dst_name, ino=ino, new_cos_key=new_cos_key,
                  nl_version=self.nl_version)
        self._dentries.pop((src_parent, src_name), None)
        self._lease_drop(src_parent)
        self._lease_drop(dst_parent)
        self._lease_drop(ino)
        if self.cfg.consistency == "weak":
            self._dentries[(dst_parent, dst_name)] = ino
        self._attrs.pop(ino, None)

    def truncate(self, ino: int, new_size: int) -> None:
        owner = self.ring.node_for(meta_key(ino))
        self._rpc(owner, "coord_truncate", client_id=self.client_id,
                  seq=self.next_seq(), ino=ino, new_size=new_size,
                  mtime=self.clock.now, nl_version=self.nl_version)
        self.invalidate_ino(ino)

    # =====================================================================
    # data path
    # =====================================================================
    def _chunks_spanned(self, off: int, length: int) -> list[int]:
        cs = self.chunk_size
        first = (off // cs) * cs
        last = ((off + max(length, 1) - 1) // cs) * cs
        return list(range(first, last + cs, cs))

    def write_chunks(self, ino: int, off: int, data: bytes, seq: int
                     ) -> list[tuple[int, list[str]]]:
        """§5.3: transfer chunk updates directly to participants, outside any
        metadata lock.  Returns [(chunk_off, [stage_ids])] for the flush.

        A ring change under the write (ESTALE, or a migration read-only
        window) re-pulls the node list and re-stages *every* part: staged
        entries are not migrated, so a partial re-stage could strand stage
        ids on old owners and the flush would silently promote nothing."""
        for attempt in range(self.cfg.max_retries):
            try:
                return self._stage_parts(ino, off, data, seq)
            except FSError as e:
                if e.errno not in (Errno.ESTALE, Errno.ECONFLICT) or \
                        attempt == self.cfg.max_retries - 1:
                    raise
                if e.errno == Errno.ECONFLICT:
                    self._bump("conflict_retries")
                self.clock.sleep(self._backoff(attempt,
                                               getattr(e, "why", None)))
                self._pull_node_list()
            except (SimTimeout, SimCrash):
                # stale ring naming a departed/dead owner: same recovery as
                # the metadata paths in _rpc
                if attempt == self.cfg.max_retries - 1:
                    raise
                self.clock.sleep(self.router.timeout_s)
                self._pull_node_list()
        raise AssertionError("unreachable")  # pragma: no cover

    def _stage_parts(self, ino: int, off: int, data: bytes, seq: int
                     ) -> list[tuple[int, list[str]]]:
        cs = self.chunk_size
        staged: dict[int, list[str]] = {}
        pos = 0
        part = 0
        ends = []
        bp_delay = 0.0
        t0 = self.clock.now
        adm0 = self.router.tenant_delay_s(self.cfg.tenant)
        while pos < len(data):
            abs_off = off + pos
            coff = (abs_off // cs) * cs
            in_off = abs_off - coff
            n = min(cs - in_off, len(data) - pos)
            stage_id = f"{self.client_id}.{seq}.{part}"
            owner = self.ring.node_for(chunk_key(ino, coff))
            # parallel transfers: all start at t0
            res, te = self.router.rpc(
                self.local_node, owner, "rpc_stage_write", t0,
                nbytes_out=n + 256,
                embedded_local=self._is_embedded(owner),
                tenant=self.cfg.tenant,
                ino=ino, chunk_off=coff, off=in_off,
                data=data[pos:pos + n], stage_id=stage_id,
                nl_version=self.nl_version)
            ends.append(te)
            bp_delay = max(bp_delay, res.get("bp_delay", 0.0))
            staged.setdefault(coff, []).append(stage_id)
            pos += n
            part += 1
        if ends:
            self.clock.advance_to(max(ends))
        if bp_delay > 0.0:
            # dirty-page backpressure (§5.2): the cluster is above its dirty
            # high-watermark — stall this writer so the flusher can drain.
            # QoS admission may already have delayed this op's staging
            # envelopes; the two throttles compose (only the remainder of
            # the hint stalls) instead of double-counting the same slowdown.
            adm = self.router.tenant_delay_s(self.cfg.tenant) - adm0
            eff = max(0.0, bp_delay - adm)
            if eff > 0.0:
                self.clock.sleep(eff)
                self._bump("bp_stalls")
                self._bump("bp_stall_s", eff)
        self._bump("write_bytes", len(data))
        return [(c, ids) for c, ids in sorted(staged.items())]

    def flush_write(self, ino: int, staged: list, new_size: int,
                    seq: int) -> None:
        owner = self.ring.node_for(meta_key(ino))
        self._rpc(owner, "coord_flush_write", client_id=self.client_id,
                  seq=seq, ino=ino, staged=staged, new_size=new_size,
                  mtime=self.clock.now, nl_version=self.nl_version)
        self._lease_drop(ino)   # our own commit bumped the epoch
        if self.cfg.consistency == "weak" and ino in self._attrs:
            self._attrs[ino]["size"] = new_size

    def read_range(self, ino: int, off: int, length: int, meta: dict,
                   handle: _Handle | None = None) -> bytes:
        """Assemble [off, off+length) from page cache / stream cache /
        cluster-local cache, with chunk-granular readahead."""
        size = meta["size"]
        length = max(0, min(length, size - off))
        if length == 0:
            return b""
        cs = self.chunk_size
        needed = self._chunks_spanned(off, length)
        weak = self.cfg.consistency == "weak"
        version = meta.get("version")

        # readahead decision: sequential if this read continues the last one
        ra = 0
        if handle is not None:
            if handle.last_read_end in (off, -1):
                ra = self.cfg.readahead_chunks
            handle.last_read_end = off + length
        fetch = list(needed)
        if ra:
            nxt = needed[-1] + cs
            while len(fetch) < len(needed) + ra and nxt < size:
                fetch.append(nxt)
                nxt += cs

        got: dict[int, bytes] = {}
        ready: dict[int, float] = {}
        t0 = self.clock.now
        for coff in fetch:
            cached = None
            if weak:
                # close-to-open: entries are valid for the inode version
                # observed at open(); a newer version forces a refetch
                ent = self._page_get(ino, coff, version)
                if ent is not None:
                    cached, ready[coff] = ent
            elif handle is not None:
                ent = handle.stream_cache.get(coff)
                if ent is not None and ent[2] == version:
                    cached = ent[0]
                    ready[coff] = ent[1]
                    self._bump("stream_hits")
            if cached is not None:
                got[coff] = cached
                continue
            owner = self.ring.node_for(chunk_key(ino, coff))
            want = min(cs, size - coff)
            res, te = self.router.rpc(
                self.local_node, owner, "rpc_read_chunk", t0,
                nbytes_in=want + 256,
                embedded_local=self._is_embedded(owner),
                tenant=self.cfg.tenant,
                ino=ino, chunk_off=coff, off=0, length=want,
                cos_bucket=meta.get("cos_bucket"),
                cos_key=meta.get("cos_key"), file_size=size,
                nl_version=self.nl_version)
            got[coff] = res
            ready[coff] = te
            self._bump("chunk_fetches")
            if weak:
                self._page_put(ino, coff, res, te, version or 0)
            elif handle is not None:
                handle.stream_cache[coff] = (res, te, version or 0)
        # the foreground read waits only for the chunks it returns; readahead
        # chunks complete in the background (their ready time is recorded in
        # the page/stream cache and charged when consumed)
        need_end = max((ready[c] for c in needed if c in ready), default=t0)
        self.clock.advance_to(max(t0, need_end))
        # copy-to-application cost: node memory bandwidth bounds cache hits
        self.clock.sleep(length / self.router.hw.mem_bps)

        out = bytearray()
        for coff in needed:
            data = got.get(coff, b"")
            s = max(off, coff) - coff
            e = min(off + length, coff + cs) - coff
            chunk_data = data if len(data) >= e else \
                data + b"\0" * (e - len(data))
            out += chunk_data[s:e]
        self._bump("read_bytes", len(out))
        return bytes(out)

    # =====================================================================
    # persistence
    # =====================================================================
    def fsync_ino(self, ino: int) -> str:
        owner = self.ring.node_for(meta_key(ino))
        res = self._rpc(owner, "coord_persist", ino=ino,
                        client_id=self.client_id, seq=self.next_seq())
        return res.get("outcome", "?")
