"""objcache core: elastic transactional cache filesystem over external storage.

Public surface::

    from repro.core import (Cluster, BucketMount, ObjcacheClient, ObjcacheFS,
                            ClientConfig, ServerConfig, CosStore, SimClock,
                            HardwareModel)

The server side is layered (see ARCHITECTURE.md): `ServerState` is the
shared state seam, and `Participant` / `Coordinator` / `Persister` /
`Migrator` are the subsystems the `CacheServer` façade wires together.
"""

from .client import ClientConfig, ObjcacheClient
from .cluster import Cluster, ScaleStats
from .coordinator import Coordinator
from .cos import (BackendProfile, CosCapacityError, CosError, CosStore,
                  CosThrottleError, GcsStore, NvmeStore, ObjectBackend)
from .flusher import BackgroundFlusher
from .fs import ObjcacheFS
from .hashring import HashRing
from .loadgen import (OnOffArrivals, OpEvent, OpenLoopRunner, PoissonArrivals,
                      Schedule, TenantSpec, TraceArrivals, build_schedule,
                      default_qos_policy, fs_fingerprint, jain_index,
                      loadtest_hw, summarize)
from .migration import Migrator
from .net import (AdmissionControl, Router, RpcSpec, SimCrash, SimTimeout,
                  TenantQos, UnknownRpcError, rpc_handler)
from .participant import Participant
from .persist import Persister
from .raftlog import ChecksumError, RaftLog
from .server import BucketMount, CacheServer, NODELIST_KEY, ServerConfig
from .simclock import HardwareModel, InflightWindow, Resource, SimClock
from .state import ServerState
from .tiering import TierPolicy, TieredStore, eviction_priority
from .types import (AdmissionError, CHUNK_SIZE_DEFAULT, Cmd, Errno, FSError,
                    InodeKind, InodeMeta, ROOT_INODE, TxId)

__all__ = [
    "AdmissionControl", "AdmissionError", "BackendProfile",
    "BackgroundFlusher", "BucketMount", "CHUNK_SIZE_DEFAULT", "CacheServer",
    "ChecksumError", "ClientConfig", "Cluster", "Cmd", "Coordinator",
    "CosCapacityError", "CosError", "CosStore", "CosThrottleError", "Errno",
    "FSError", "GcsStore", "HardwareModel", "HashRing", "InflightWindow",
    "InodeKind", "InodeMeta", "Migrator", "NODELIST_KEY", "NvmeStore",
    "ObjcacheClient", "ObjcacheFS", "ObjectBackend", "OnOffArrivals",
    "OpEvent", "OpenLoopRunner", "Participant", "Persister",
    "PoissonArrivals", "ROOT_INODE", "Resource", "Router", "RaftLog",
    "RpcSpec", "ScaleStats", "Schedule", "ServerConfig", "ServerState",
    "SimClock", "SimCrash", "SimTimeout", "TenantQos", "TenantSpec",
    "TierPolicy", "TieredStore", "TraceArrivals", "TxId", "UnknownRpcError",
    "build_schedule", "default_qos_policy", "eviction_priority",
    "fs_fingerprint", "jain_index", "loadtest_hw", "rpc_handler",
    "summarize",
]
