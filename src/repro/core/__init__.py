"""objcache core: elastic transactional cache filesystem over external storage.

Public surface::

    from repro.core import (Cluster, BucketMount, ObjcacheClient, ObjcacheFS,
                            ClientConfig, ServerConfig, CosStore, SimClock,
                            HardwareModel)
"""

from .client import ClientConfig, ObjcacheClient
from .cluster import Cluster, ScaleStats
from .cos import CosError, CosStore
from .fs import ObjcacheFS
from .hashring import HashRing
from .net import Router, SimCrash, SimTimeout
from .raftlog import ChecksumError, RaftLog
from .server import BucketMount, CacheServer, ServerConfig
from .simclock import HardwareModel, Resource, SimClock
from .types import (CHUNK_SIZE_DEFAULT, Cmd, Errno, FSError, InodeKind,
                    InodeMeta, ROOT_INODE, TxId)

__all__ = [
    "BucketMount", "CHUNK_SIZE_DEFAULT", "CacheServer", "ChecksumError",
    "ClientConfig", "Cluster", "Cmd", "CosError", "CosStore", "Errno",
    "FSError", "HardwareModel", "HashRing", "InodeKind", "InodeMeta",
    "ObjcacheClient", "ObjcacheFS", "ROOT_INODE", "Resource", "Router",
    "RaftLog", "ScaleStats", "ServerConfig", "SimClock", "SimCrash",
    "SimTimeout", "TxId",
]
