"""Shared per-server state — the explicit seam between server subsystems.

`ServerState` owns everything a `CacheServer` used to keep as instance
attributes: the durable-log handle, the working tables rebuilt by replay
(§3.4), transaction bookkeeping, the node list/ring, and the stats counters
the benchmarks read.  The four subsystems (`participant`, `coordinator`,
`persist`, `migration`) and the `CacheServer` façade all hold a reference to
the *same* `ServerState`, so a WAL replay that swaps the tables is visible
everywhere at once.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

from .hashring import HashRing
from .net import Router, SimCrash, SimTimeout
from .raftlog import RaftLog
from .simclock import HardwareModel, Resource, SimClock
from .stores import ChunkTable, MetaTable
from .txn import LockTable, TxTable
from .types import Errno, FSError, StaleLeaseError

if TYPE_CHECKING:  # pragma: no cover
    from .cos import CosStore
    from .server import ServerConfig

NODELIST_KEY = "__nodelist__"
_INO_SHIFT = 40


@dataclass
class ServerState:
    """All mutable + wiring state of one cache-server process."""

    # ---- identity / wiring (never changes after construction) -----------
    node_id: str
    server_uid: int
    workdir: str
    clock: SimClock
    router: Router
    cos: "CosStore"
    hw: HardwareModel
    cfg: "ServerConfig"
    raft: RaftLog
    disk: Resource
    nic: Resource

    # ---- storage-backend binding (tiered multi-backend storage) ---------
    # named backends shared cluster-wide ({name: ObjectBackend|TieredStore})
    # and the per-bucket binding derived from the BucketMounts; an unbound
    # bucket (or one bound to the reserved name "cos") resolves to the
    # swappable default `self.cos`, preserving the pre-tiering behaviour
    backends: dict[str, object] = field(default_factory=dict)
    bucket_backends: dict[str, str] = field(default_factory=dict)

    # ---- working tables, rebuilt exactly by WAL replay (§3.4) -----------
    metas: MetaTable = field(default_factory=MetaTable)
    chunks: ChunkTable = field(default_factory=ChunkTable)
    locks: LockTable = field(default_factory=LockTable)
    txs: TxTable = field(default_factory=TxTable)
    node_list: list[str] = field(default_factory=list)
    node_list_version: int = 0
    ring: HashRing = field(default_factory=HashRing)

    # ---- lifecycle -------------------------------------------------------
    read_only: bool = False
    alive: bool = True

    # ---- counters / transaction bookkeeping ------------------------------
    ino_counter: int = 1
    txseq: int = 1
    # per-inode lease epochs (metadata fast path): bumped by every committed
    # mutation of the inode's metadata/namespace and by migration handoff.
    # Bumps happen inside the WAL apply path, so replay re-derives the same
    # epochs and a restarted owner keeps rejecting stale leases.
    lease_epochs: dict[int, int] = field(default_factory=dict)
    # coordinator dedup: (client_id, seq) -> (txseq, outcome)
    coord_done: dict[tuple[int, int], tuple[int, str]] = field(
        default_factory=dict)
    # in-doubt coordinator transactions found by replay (txseq -> info)
    coord_pending: dict[int, dict] = field(default_factory=dict)
    # MPUs this coordinator began but has not committed/aborted yet
    # (upload_id -> {ino, bucket, key}); rebuilt by replay so a restarted
    # coordinator can abort the orphan uploads (Fig. 8 black dots)
    mpu_pending: dict[str, dict] = field(default_factory=dict)
    # crash injection points (names match Fig. 8 black dots)
    crash_points: set[str] = field(default_factory=set)
    # stats for benchmarks (per-method RPC stats land here too)
    stats: dict[str, float] = field(default_factory=dict)

    # =====================================================================
    # lifecycle / failure injection
    # =====================================================================
    def make_lock_table(self) -> LockTable:
        return LockTable(queue_depth=self.cfg.lock_queue_depth,
                         reservation_ttl_s=self.cfg.lock_reservation_ttl_s)

    def reset_tables(self) -> None:
        """Drop all replay-derived state ahead of a WAL replay."""
        self.metas = MetaTable()
        self.chunks = ChunkTable()
        self.locks = self.make_lock_table()
        self.txs = TxTable()
        self.node_list, self.node_list_version = [], 0
        self.ring = HashRing()
        self.ino_counter = 1
        self.coord_done, self.coord_pending = {}, {}
        self.mpu_pending = {}
        self.lease_epochs = {}

    def arm_crash(self, point: str) -> None:
        self.crash_points.add(point)

    def crash_at(self, point: str) -> None:
        if point in self.crash_points:
            self.crash_points.discard(point)
            self.alive = False
            raise SimCrash(self.node_id, point)

    # =====================================================================
    # request guards
    # =====================================================================
    def check_alive(self) -> None:
        if not self.alive:
            raise SimTimeout(f"{self.node_id} is down")

    def check_nl(self, nl_version: int | None) -> None:
        """§4.3: every request carries the client's node-list version."""
        if nl_version is not None and nl_version != self.node_list_version:
            raise FSError(Errno.ESTALE,
                          f"node list v{nl_version} != "
                          f"v{self.node_list_version}")

    def check_writable(self) -> None:
        if self.read_only:
            raise FSError(Errno.ECONFLICT, "server is read-only (migrating)")

    # =====================================================================
    # client leases (metadata fast path)
    # =====================================================================
    def lease_epoch(self, ino: int) -> int:
        return self.lease_epochs.get(ino, 0)

    def bump_lease(self, ino: int) -> None:
        self.lease_epochs[ino] = self.lease_epochs.get(ino, 0) + 1

    def lease_grant(self, ino: int) -> dict | None:
        """Lease descriptor attached to lookup/readdir/getattr replies; None
        when leases are disabled (`lease_ttl_s <= 0`)."""
        ttl = self.cfg.lease_ttl_s
        if ttl <= 0:
            return None
        return {"ino": ino, "epoch": self.lease_epoch(ino), "ttl": ttl}

    def check_lease(self, ino: int, lease_epoch: int | None) -> None:
        """Reject a renewal that carries a stale epoch: some mutation
        committed (or the inode migrated in) since the lease was granted."""
        if lease_epoch is None:
            return
        cur = self.lease_epoch(ino)
        if lease_epoch != cur:
            self.bump("lease_stale")
            raise StaleLeaseError(ino, lease_epoch, cur)

    # =====================================================================
    # storage-backend binding
    # =====================================================================
    def backend_for(self, bucket: str | None):
        """Resolve a bucket to its bound storage backend.  The reserved
        binding name "cos" (and any unbound bucket) resolves to the
        swappable default `self.cos` — tests and benchmarks that splice a
        shared external store across cold restarts keep working, and a
        cluster built without explicit backends is bit-identical to the
        pre-tiering single store."""
        name = self.bucket_backends.get(bucket or "", "cos")
        if name == "cos":
            return self.cos
        return self.backends[name]

    # =====================================================================
    # placement / allocation helpers
    # =====================================================================
    def owner(self, key: str) -> str:
        return self.ring.node_for(key)

    def chunk_offsets(self, size: int) -> list[int]:
        cs = self.cfg.chunk_size
        if size <= 0:
            return [0]
        return list(range(0, size, cs))

    def note_ino(self, ino: int) -> None:
        if (ino >> _INO_SHIFT) == self.server_uid:
            self.ino_counter = max(self.ino_counter,
                                   (ino & ((1 << _INO_SHIFT) - 1)) + 1)

    def alloc_ino(self) -> int:
        ino = (self.server_uid << _INO_SHIFT) | self.ino_counter
        self.ino_counter += 1
        return ino

    def bump(self, stat: str, n: float = 1) -> None:
        self.stats[stat] = self.stats.get(stat, 0) + n

    # =====================================================================
    # dirty-page accounting / backpressure (§5.2 write-back pipeline)
    # =====================================================================
    def dirty_bytes(self) -> int:
        """Locally held bytes of dirty chunks on this node — the quantity
        the flusher's watermarks govern."""
        return sum(c.local_bytes() for c in self.chunks.chunks.values()
                   if c.dirty)

    def backpressure_delay(self) -> float:
        """Stall to impose on a foreground staged write while dirty bytes
        sit above the high-watermark.  Grows with the overflow so writers
        cannot outrun the flusher indefinitely; 0 below the watermark."""
        hi = self.cfg.dirty_hiwater_bytes
        if hi <= 0:
            return 0.0
        db = self.dirty_bytes()
        if db <= hi:
            return 0.0
        overflow = (db - hi) / max(1.0, hi - self.cfg.dirty_lowater_bytes)
        return self.cfg.backpressure_stall_s * min(8.0, 1.0 + overflow)
