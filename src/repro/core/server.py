"""Cache server: Raft state machine + 2PC participant/coordinator (§4–§5).

One `CacheServer` is the paper's "cluster-local cache" process on one node.
It owns a shard of the namespace (consistent hashing over metadata keys and
chunk keys), a two-level Raft WAL, and plays all three transaction roles:

* **participant** — `rpc_prepare` / `rpc_commit` / `rpc_abort` with TxId dedup;
* **coordinator** — `coord_execute` drives the 2PC over the router; the
  single-node fast path commutes to one local log append (§4.4);
* **persisting coordinator** — `coord_persist` is Fig. 8's mixed transaction
  with COS multipart upload as an additional participant (MPU begin recorded
  *before* commit so a crash can abort the upload; PutObject fast path for
  sub-chunk inodes).

All state mutations flow through `_log` (durable append, then `_apply`), so a
crashed server rebuilds exactly by replay; `recover_pending` then re-drives
in-doubt coordinator decisions (§4.4: "after a log replay, objcache can resume
committing or aborting updates").
"""

from __future__ import annotations

import posixpath
from dataclasses import dataclass, field
from typing import Any

from .cos import CosError, CosStore
from .hashring import HashRing
from .net import Router, SimCrash, SimTimeout
from .raftlog import BulkRef, RaftLog
from .simclock import HardwareModel, SimClock
from .stores import ChunkState, ChunkTable, MetaTable, Segment, StagedWrite
from .txn import (LockTable, PreparedOp, PreparedTx, TxTable, txid_from_payload,
                  txid_payload)
from .types import (CHUNK_SIZE_DEFAULT, Cmd, Errno, FSError, InodeKind,
                    InodeMeta, ROOT_INODE, TxId, chunk_key, meta_key)

NODELIST_KEY = "__nodelist__"
_INO_SHIFT = 40


@dataclass
class ServerConfig:
    chunk_size: int = CHUNK_SIZE_DEFAULT
    flush_interval_s: float = 10.0
    # paper §6.3: prefetch parallelism for COS range reads
    cos_part_parallel: int = 64
    rpc_timeout_s: float = 1.0


@dataclass
class BucketMount:
    """One external bucket mounted at /<dirname> (§3.1, Fig. 3a)."""

    dirname: str
    bucket: str


class CacheServer:
    def __init__(self, node_id: str, server_uid: int, workdir: str,
                 clock: SimClock, router: Router, cos: CosStore,
                 hw: HardwareModel, cfg: ServerConfig | None = None,
                 buckets: list[BucketMount] | None = None) -> None:
        self.node_id = node_id
        self.server_uid = server_uid
        self.clock = clock
        self.router = router
        self.cos = cos
        self.hw = hw
        self.cfg = cfg or ServerConfig()
        self.buckets = buckets or []
        self.disk = hw.make_disk(node_id)
        self.nic = hw.make_nic(node_id)
        self.workdir = workdir
        self.raft = RaftLog(workdir, clock, self.disk)

        self.metas = MetaTable()
        self.chunks = ChunkTable()
        self.locks = LockTable()
        self.txs = TxTable()
        self.node_list: list[str] = []
        self.node_list_version: int = 0
        self.ring: HashRing = HashRing()
        self.read_only = False
        self.alive = True
        self._ino_counter = 1
        self._txseq = 1
        # coordinator dedup: (client_id, seq) -> (txseq, outcome)
        self._coord_done: dict[tuple[int, int], tuple[int, str]] = {}
        # in-doubt coordinator transactions found by replay (txseq -> info)
        self._coord_pending: dict[int, dict] = {}
        # crash injection points (names match Fig. 8 black dots)
        self._crash_points: set[str] = set()
        # stats for benchmarks
        self.stats: dict[str, int] = {}
        router.register(self)

    # =====================================================================
    # lifecycle / failure injection
    # =====================================================================
    def arm_crash(self, point: str) -> None:
        self._crash_points.add(point)

    def _crash_at(self, point: str) -> None:
        if point in self._crash_points:
            self._crash_points.discard(point)
            self.alive = False
            raise SimCrash(self.node_id, point)

    def crash(self) -> None:
        """Hard-kill: nothing flushed beyond what the WAL already holds."""
        self.alive = False

    def restart(self, start: float | None = None) -> float:
        """Replay the WAL and rebuild all state (§3.4)."""
        t0 = self.clock.now if start is None else start
        self.metas = MetaTable()
        self.chunks = ChunkTable()
        self.locks = LockTable()
        self.txs = TxTable()
        self.node_list, self.node_list_version = [], 0
        self.ring = HashRing()
        self._ino_counter, self._coord_done, self._coord_pending = 1, {}, {}
        nbytes = 0
        for entry in self.raft.replay():
            self._apply(entry.cmd, entry.payload)
            nbytes += 64 + len(str(entry.payload))
        self.raft.bump_term()
        self.alive = True
        self.read_only = False
        # replay charges a sequential disk read of the whole log
        end = self.disk.acquire(t0, self.raft.size_bytes())
        self.clock.advance_to(end)
        return end

    def recover_pending(self, start: float) -> float:
        """Re-drive in-doubt coordinator transactions after replay (§4.4)."""
        t = start
        for txseq, info in sorted(self._coord_pending.items()):
            txid = txid_from_payload(info["txid"])
            nodes = list(info["nodes"])
            if info["decided"] == "commit":
                t = self._send_decision(txid, nodes, commit=True, start=t)
            else:  # undecided or decided-abort: abort is always safe pre-commit
                t = self._send_decision(txid, nodes, commit=False, start=t)
        self._coord_pending.clear()
        return t

    # =====================================================================
    # durable log + state machine
    # =====================================================================
    def _log(self, cmd: Cmd, payload: dict, start: float) -> float:
        _, end = self.raft.append(cmd, payload, start=start)
        self._apply(cmd, payload)
        return end

    def _apply(self, cmd: Cmd, p: dict) -> None:
        if cmd in (Cmd.TX_PREPARE_META, Cmd.TX_PREPARE_CHUNK,
                   Cmd.TX_PREPARE_DIR, Cmd.TX_PREPARE_NODELIST):
            txid = txid_from_payload(p["txid"])
            tx = self.txs.prepared.get(txid) or PreparedTx(txid)
            for op in p["ops"]:
                tx.ops.append(PreparedOp(cmd, op))
            keys = p.get("keys", [])
            tx.locked_keys.extend(keys)
            self.locks.try_acquire(keys, txid)
            self.txs.put_prepared(tx)
        elif cmd == Cmd.TX_COMMIT:
            txid = txid_from_payload(p["txid"])
            tx = self.txs.pop_prepared(txid)
            if tx is not None:
                for op in tx.ops:
                    self._apply_op(op.payload)
            self.locks.release(txid)
            self.txs.record_completed(txid, "commit")
        elif cmd == Cmd.TX_ABORT:
            txid = txid_from_payload(p["txid"])
            self.txs.pop_prepared(txid)
            self.locks.release(txid)
            self.txs.record_completed(txid, "abort")
        elif cmd in (Cmd.LOCAL_META_UPDATE, Cmd.LOCAL_CHUNK_COMMIT,
                     Cmd.LOCAL_DIR_UPDATE):
            for op in p["ops"]:
                self._apply_op(op)
        elif cmd == Cmd.CHUNK_STAGE:
            c = self.chunks.ensure(p["ino"], p["chunk_off"])
            c.staged[p["stage_id"]] = StagedWrite(
                p["stage_id"], p["off"], p["length"],
                BulkRef.from_payload(p["ref"]))
        elif cmd == Cmd.CHUNK_FILL_FROM_COS:
            c = self.chunks.ensure(p["ino"], p["chunk_off"])
            c.base_filled.append(Segment(p["off"], p["length"],
                                         BulkRef.from_payload(p["ref"])))
        elif cmd in (Cmd.EVICT_META,):
            self.metas.evict(p["ino"])
        elif cmd in (Cmd.EVICT_CHUNK,):
            self.chunks.evict(p["ino"], p["chunk_off"])
        elif cmd == Cmd.MIGRATE_RECV_META or cmd == Cmd.MIGRATE_RECV_DIR:
            meta = InodeMeta.from_payload(p["meta"])
            self.metas.put(meta)
            self._note_ino(meta.ino)
        elif cmd == Cmd.MIGRATE_RECV_CHUNK:
            c = ChunkState.from_payload(p["chunk"])
            self.chunks.chunks[(c.ino, c.chunk_off)] = c
        elif cmd == Cmd.TX_COORD_BEGIN:
            self._txseq = max(self._txseq, p["txid"]["txseq"] + 1)
            self._coord_pending[p["txid"]["txseq"]] = {
                "txid": p["txid"], "nodes": p["nodes"], "decided": None}
        elif cmd == Cmd.TX_COORD_DECIDE_COMMIT:
            info = self._coord_pending.get(p["txseq"])
            if info is not None:
                info["decided"] = "commit"
            self._coord_done[(p["client_id"], p["seq"])] = (p["txseq"], "commit")
        elif cmd == Cmd.TX_COORD_DECIDE_ABORT:
            info = self._coord_pending.get(p["txseq"])
            if info is not None:
                info["decided"] = "abort"
            self._coord_done[(p["client_id"], p["seq"])] = (p["txseq"], "abort")
        elif cmd in (Cmd.MPU_BEGIN_RECORDED, Cmd.MPU_COMMITTED,
                     Cmd.PUT_OBJECT_DONE, Cmd.COS_DELETE_DONE):
            pass  # audit records consumed by recovery (abort orphan MPUs)
        elif cmd in (Cmd.DIRTY_CLEARED_CHUNK,):
            c = self.chunks.get(p["ino"], p["chunk_off"])
            if c is not None and c.version == p["version"]:
                c.dirty = False
        elif cmd in (Cmd.DIRTY_CLEARED_META,):
            m = self.metas.get(p["ino"])
            if m is not None and m.version == p["version"]:
                m.dirty = False
                m.cos_old_keys = []
        elif cmd == Cmd.NODE_JOIN or cmd == Cmd.NODE_LEAVE:
            pass  # audit-only; the node list itself moves via nodelist_set ops
        elif cmd == Cmd.SNAPSHOT:
            self._load_snapshot(p)
        else:  # pragma: no cover
            raise AssertionError(f"unknown cmd {cmd}")

    def _apply_op(self, op: dict) -> None:
        """Redo-op application — the only place working state mutates."""
        kind = op["kind"]
        if kind == "meta_put":
            meta = InodeMeta.from_payload(op["meta"])
            self.metas.put(meta)
            self._note_ino(meta.ino)
        elif kind == "meta_set":
            m = self.metas.get(op["ino"])
            if m is None:
                return
            for f in ("size", "mtime", "dirty", "deleted", "mode",
                      "cos_bucket", "cos_key", "loaded"):
                if f in op:
                    setattr(m, f, op[f])
            if "add_old_key" in op and op["add_old_key"]:
                if op["add_old_key"] not in m.cos_old_keys:
                    m.cos_old_keys.append(op["add_old_key"])
            m.version += 1
        elif kind == "meta_evict":
            self.metas.evict(op["ino"])
        elif kind == "dir_link":
            d = self.metas.get(op["ino"])
            if d is None:
                return
            d.children[op["name"]] = op["child"]
            d.mtime = op.get("mtime", d.mtime)
            d.version += 1
            d.dirty = True
        elif kind == "dir_set_children":
            d = self.metas.get(op["ino"])
            if d is None:
                return
            d.children.update({k: int(v) for k, v in op["children"].items()})
            d.loaded = bool(op.get("loaded", d.loaded))
            d.version += 1
        elif kind == "dir_unlink":
            d = self.metas.get(op["ino"])
            if d is None:
                return
            d.children.pop(op["name"], None)
            d.mtime = op.get("mtime", d.mtime)
            d.version += 1
            d.dirty = True
        elif kind == "chunk_promote":
            c = self.chunks.ensure(op["ino"], op["chunk_off"])
            for sid in op["stage_ids"]:
                sw = c.staged.pop(sid, None)
                if sw is not None:
                    c.segments.append(Segment(sw.off, sw.length, sw.ref))
            c.version += 1
            c.dirty = True
            c.deleted = False
        elif kind == "chunk_zero_tail":
            c = self.chunks.ensure(op["ino"], op["chunk_off"])
            c.segments.append(Segment(op["from"], op["length"], None))
            c.version += 1
            c.dirty = True
        elif kind == "chunk_delete":
            c = self.chunks.ensure(op["ino"], op["chunk_off"])
            c.deleted = True
            c.dirty = True
            c.version += 1
            c.base_filled, c.segments, c.staged = [], [], {}
        elif kind == "chunk_evict":
            self.chunks.evict(op["ino"], op["chunk_off"])
        elif kind == "nodelist_set":
            self.node_list = list(op["nodes"])
            self.node_list_version = op["version"]
            self.ring = HashRing(self.node_list)
        else:  # pragma: no cover
            raise AssertionError(f"unknown op kind {kind}")

    def _note_ino(self, ino: int) -> None:
        if (ino >> _INO_SHIFT) == self.server_uid:
            self._ino_counter = max(self._ino_counter,
                                    (ino & ((1 << _INO_SHIFT) - 1)) + 1)

    def alloc_ino(self) -> int:
        ino = (self.server_uid << _INO_SHIFT) | self._ino_counter
        self._ino_counter += 1
        return ino

    # ---- snapshot/compaction -------------------------------------------------
    def snapshot_payload(self) -> dict:
        return {
            "node_list": self.node_list, "nl_version": self.node_list_version,
            "ino_counter": self._ino_counter,
            "metas": {str(i): m.to_payload() for i, m in self.metas.inodes.items()},
        }

    def _load_snapshot(self, p: dict) -> None:
        self.node_list = list(p["node_list"])
        self.node_list_version = p["nl_version"]
        self.ring = HashRing(self.node_list)
        self._ino_counter = p["ino_counter"]
        for mp in p["metas"].values():
            self.metas.put(InodeMeta.from_payload(mp))

    # =====================================================================
    # helpers
    # =====================================================================
    def _check_alive(self) -> None:
        if not self.alive:
            raise SimTimeout(f"{self.node_id} is down")

    def _check_nl(self, nl_version: int | None) -> None:
        """§4.3: every request carries the client's node-list version."""
        if nl_version is not None and nl_version != self.node_list_version:
            raise FSError(Errno.ESTALE,
                          f"node list v{nl_version} != v{self.node_list_version}")

    def _check_writable(self) -> None:
        if self.read_only:
            raise FSError(Errno.ECONFLICT, "server is read-only (migrating)")

    def owner(self, key: str) -> str:
        return self.ring.node_for(key)

    def chunk_offsets(self, size: int) -> list[int]:
        cs = self.cfg.chunk_size
        if size <= 0:
            return [0]
        return list(range(0, size, cs))

    def _bump(self, stat: str, n: int = 1) -> None:
        self.stats[stat] = self.stats.get(stat, 0) + n

    # =====================================================================
    # read-side RPCs (no transaction; §3.3 servers always see committed state)
    # =====================================================================
    def rpc_getattr(self, start: float, ino: int,
                    nl_version: int | None = None) -> tuple[dict, float]:
        self._check_alive()
        self._check_nl(nl_version)
        m = self.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        return m.to_payload(), start

    def rpc_lookup(self, start: float, parent: int, name: str,
                   nl_version: int | None = None) -> tuple[dict, float]:
        """Single-name lookup in a parent directory this server owns."""
        self._check_alive()
        self._check_nl(nl_version)
        d = self.metas.get(parent)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"parent {parent}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"parent {parent}")
        child = d.children.get(name)
        if child is None:
            raise FSError(Errno.ENOENT, f"{parent}/{name}")
        return {"ino": child}, start

    def rpc_readdir(self, start: float, ino: int,
                    nl_version: int | None = None) -> tuple[dict, float]:
        self._check_alive()
        self._check_nl(nl_version)
        d = self.metas.get(ino)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"ino {ino}")
        return {"children": dict(d.children), "loaded": d.loaded}, start

    def rpc_read_chunk(self, start: float, ino: int, chunk_off: int, off: int,
                       length: int, cos_bucket: str | None,
                       cos_key: str | None, file_size: int,
                       nl_version: int | None = None) -> tuple[bytes, float]:
        """Read [off, off+length) within one chunk; fills from COS on miss
        (§5.4: each predecessor downloads its own range of the inode)."""
        self._check_alive()
        self._check_nl(nl_version)
        c = self.chunks.get(ino, chunk_off)
        cover_len = max(0, min(self.cfg.chunk_size, file_size - chunk_off))
        t = start
        if (c is None or not c.covered(off, min(length, cover_len - off))) \
                and cos_bucket and cos_key and cover_len > 0 \
                and self.cos.exists(cos_bucket, cos_key):
            # cache miss: fetch this chunk's whole range of the object once
            self._bump("cos_fill")
            data, t = self.cos.get_object(cos_bucket, cos_key,
                                          rng=(chunk_off, cover_len), start=t)
            ref, t = self.raft.append_bulk(data, start=t)
            t = self._log(Cmd.CHUNK_FILL_FROM_COS,
                          {"ino": ino, "chunk_off": chunk_off, "off": 0,
                           "length": len(data), "ref": ref.to_payload()}, t)
            c = self.chunks.get(ino, chunk_off)
        if c is None:
            return b"\0" * length, t
        want = min(length, max(cover_len, c.local_bytes()) - off)
        if want <= 0:
            return b"", t
        buf = c.materialize(self.raft, off + want)[off:off + want]
        # local disk read of the materialized bytes
        t = self.disk.acquire(t, len(buf))
        self._bump("chunk_read_bytes", len(buf))
        return buf, t

    def rpc_nodelist(self, start: float) -> tuple[dict, float]:
        self._check_alive()
        return {"nodes": list(self.node_list),
                "version": self.node_list_version}, start

    # =====================================================================
    # write staging (§5.3: chunk transfer outside the metadata lock)
    # =====================================================================
    def rpc_stage_write(self, start: float, ino: int, chunk_off: int, off: int,
                        data: bytes, stage_id: str,
                        nl_version: int | None = None) -> tuple[dict, float]:
        self._check_alive()
        self._check_nl(nl_version)
        self._check_writable()
        ref, t = self.raft.append_bulk(bytes(data), start=start)
        t = self._log(Cmd.CHUNK_STAGE,
                      {"ino": ino, "chunk_off": chunk_off, "off": off,
                       "length": len(data), "ref": ref.to_payload(),
                       "stage_id": stage_id}, t)
        self._bump("staged_bytes", len(data))
        return {"ok": True}, t

    # =====================================================================
    # 2PC participant (§4.4)
    # =====================================================================
    def rpc_prepare(self, start: float, txid_p: dict, cmd_id: int, ops: list,
                    keys: list, nl_version: int | None = None
                    ) -> tuple[dict, float]:
        self._check_alive()
        self._check_nl(nl_version)
        txid = txid_from_payload(txid_p)
        done = self.txs.completed_outcome(txid)
        if done is not None:  # duplicated request (§4.5) — reply old result
            return {"vote": done == "commit", "dup": True}, start
        if self.txs.is_prepared(txid):  # retried prepare: already voted yes
            return {"vote": True, "dup": True}, start
        if Cmd(cmd_id) != Cmd.TX_PREPARE_NODELIST:
            # reconfiguration transactions run *during* the read-only window
            self._check_writable()
        if not self.locks.try_acquire(list(keys), txid):
            self._bump("lock_conflict")
            return {"vote": False, "why": "lock"}, start
        self._crash_at("participant_after_lock")
        t = self._log(Cmd(cmd_id), {"txid": txid_p, "ops": ops, "keys": keys},
                      start)
        self._crash_at("participant_after_prepare")
        return {"vote": True}, t

    def rpc_commit(self, start: float, txid_p: dict) -> tuple[dict, float]:
        self._check_alive()
        txid = txid_from_payload(txid_p)
        if self.txs.completed_outcome(txid) is not None:
            return {"ok": True, "dup": True}, start
        t = self._log(Cmd.TX_COMMIT, {"txid": txid_p}, start)
        self._crash_at("participant_after_commit")
        return {"ok": True}, t

    def rpc_abort(self, start: float, txid_p: dict) -> tuple[dict, float]:
        self._check_alive()
        txid = txid_from_payload(txid_p)
        if self.txs.completed_outcome(txid) is not None:
            return {"ok": True, "dup": True}, start
        t = self._log(Cmd.TX_ABORT, {"txid": txid_p}, start)
        return {"ok": True}, t

    # =====================================================================
    # 2PC coordinator (§4.4) — plan = {node_id: {"cmd": Cmd, "ops": [...],
    #                                            "keys": [...]}}
    # =====================================================================
    def coord_execute(self, start: float, client_id: int, seq: int,
                      plan: dict[str, dict]) -> tuple[dict, float]:
        self._check_alive()
        done = self._coord_done.get((client_id, seq))
        if done is not None:
            return {"outcome": done[1], "dup": True}, start
        # single-node fast path: everything on this server -> one log append
        if set(plan) == {self.node_id}:
            ent = plan[self.node_id]
            txid = TxId(client_id, seq, 0)
            if not self.locks.try_acquire(list(ent["keys"]), txid):
                raise FSError(Errno.ECONFLICT, "local lock conflict")
            try:
                self._check_writable()
                t = self._log(Cmd.LOCAL_META_UPDATE, {"ops": ent["ops"]}, start)
            finally:
                self.locks.release(txid)
            self._bump("tx_local")
            return {"outcome": "commit"}, t

        txid = TxId(client_id, seq, self._txseq)
        txid_p = txid_payload(txid)
        t = self._log(Cmd.TX_COORD_BEGIN,
                      {"txid": txid_p, "nodes": sorted(plan)}, start)
        self._crash_at("coord_after_begin")
        votes_ok, ends = True, []
        for node in sorted(plan):
            ent = plan[node]
            try:
                res, te = self.router.rpc(
                    self.node_id, node, "rpc_prepare", t,
                    nbytes_out=sum(len(str(o)) for o in ent["ops"]) + 128,
                    txid_p=txid_p, cmd_id=int(ent["cmd"]), ops=ent["ops"],
                    keys=ent["keys"], nl_version=None)
                ends.append(te)
                if not res["vote"]:
                    votes_ok = False
            except (SimTimeout, SimCrash):
                ends.append(self.router.charge_timeout(t))
                votes_ok = False
        t = max(ends) if ends else t
        decide = Cmd.TX_COORD_DECIDE_COMMIT if votes_ok \
            else Cmd.TX_COORD_DECIDE_ABORT
        t = self._log(decide, {"txseq": txid.txseq, "client_id": client_id,
                               "seq": seq}, t)
        self._crash_at("coord_after_decide")
        t = self._send_decision(txid, sorted(plan), commit=votes_ok, start=t)
        self._coord_pending.pop(txid.txseq, None)
        self._bump("tx_commit" if votes_ok else "tx_abort")
        return {"outcome": "commit" if votes_ok else "abort"}, t

    def _send_decision(self, txid: TxId, nodes: list[str], commit: bool,
                       start: float) -> float:
        txid_p = txid_payload(txid)
        method = "rpc_commit" if commit else "rpc_abort"
        ends = []
        for node in nodes:
            try:
                _, te = self.router.rpc(self.node_id, node, method, start,
                                        txid_p=txid_p)
                ends.append(te)
            except (SimTimeout, SimCrash):
                # participant will learn the outcome on recovery / retry
                ends.append(self.router.charge_timeout(start))
        return max(ends) if ends else start

    # =====================================================================
    # FS-operation coordinators — the client sends each file operation to
    # "the node for metadata as a transaction coordinator" (§4.4); the
    # coordinator builds the multi-node plan and drives the 2PC (or the
    # single-node fast path).
    # =====================================================================
    def _plan_add(self, plan: dict, node: str, op: dict, keys: list[str],
                  cmd: Cmd = Cmd.TX_PREPARE_META) -> None:
        ent = plan.setdefault(node, {"cmd": cmd, "ops": [], "keys": []})
        ent["ops"].append(op)
        for k in keys:
            if k not in ent["keys"]:
                ent["keys"].append(k)

    def _require_owner(self, key: str) -> None:
        if self.owner(key) != self.node_id:
            raise FSError(Errno.ESTALE,
                          f"{self.node_id} is not the owner of {key}")

    def coord_create(self, start: float, client_id: int, seq: int, parent: int,
                     name: str, kind: int, cos_bucket: str | None,
                     cos_key: str | None, mtime: float,
                     nl_version: int | None = None) -> tuple[dict, float]:
        """Create a file/dir: new metadata on its owner + parent dir link.
        Coordinator = parent directory owner (it allocates the inode)."""
        self._check_alive()
        self._check_nl(nl_version)
        self._require_owner(meta_key(parent))
        d = self.metas.get(parent)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"parent {parent}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"parent {parent}")
        if name in d.children:
            raise FSError(Errno.EEXIST, f"{parent}/{name}")
        ino = self.alloc_ino()
        meta = InodeMeta(ino=ino, kind=InodeKind(kind), size=0, mtime=mtime,
                         dirty=True, cos_bucket=cos_bucket, cos_key=cos_key,
                         loaded=True)
        plan: dict[str, dict] = {}
        self._plan_add(plan, self.owner(meta_key(ino)),
                       {"kind": "meta_put", "meta": meta.to_payload()},
                       [meta_key(ino)])
        self._plan_add(plan, self.node_id,
                       {"kind": "dir_link", "ino": parent, "name": name,
                        "child": ino, "mtime": mtime},
                       [meta_key(parent)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise FSError(Errno.ECONFLICT, "create aborted")
        return {"ino": ino}, t

    def coord_load_dir(self, start: float, client_id: int, seq: int, ino: int,
                       nl_version: int | None = None) -> tuple[dict, float]:
        """§3.2: materialize a directory's children from the COS listing.
        Load-once; clean child metas are created on their owner nodes."""
        self._check_alive()
        self._check_nl(nl_version)
        self._require_owner(meta_key(ino))
        d = self.metas.get(ino)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"ino {ino}")
        if d.loaded or d.cos_bucket is None:
            return {"children": dict(d.children)}, start
        prefix = d.cos_key or ""
        objs, prefixes, t = self.cos.list_prefix(d.cos_bucket, prefix,
                                                 start=start)
        plan: dict[str, dict] = {}
        new_children: dict[str, int] = {}
        for key, size in objs:
            nm = key[len(prefix):]
            if not nm or nm in d.children:
                continue
            cino = self.alloc_ino()
            meta = InodeMeta(ino=cino, kind=InodeKind.FILE, size=size,
                             dirty=False, cos_bucket=d.cos_bucket, cos_key=key,
                             loaded=True)
            new_children[nm] = cino
            self._plan_add(plan, self.owner(meta_key(cino)),
                           {"kind": "meta_put", "meta": meta.to_payload()},
                           [meta_key(cino)])
        for pfx in prefixes:
            nm = pfx[len(prefix):].rstrip("/")
            if not nm or nm in d.children:
                continue
            cino = self.alloc_ino()
            meta = InodeMeta(ino=cino, kind=InodeKind.DIR, dirty=False,
                             cos_bucket=d.cos_bucket, cos_key=pfx,
                             loaded=False)
            new_children[nm] = cino
            self._plan_add(plan, self.owner(meta_key(cino)),
                           {"kind": "meta_put", "meta": meta.to_payload()},
                           [meta_key(cino)])
        self._plan_add(plan, self.node_id,
                       {"kind": "dir_set_children", "ino": ino,
                        "children": new_children, "loaded": True},
                       [meta_key(ino)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(t, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise FSError(Errno.ECONFLICT, "load_dir aborted")
        d = self.metas.get(ino)
        self._bump("dir_loads")
        return {"children": dict(d.children) if d else {}}, t

    def coord_flush_write(self, start: float, client_id: int, seq: int,
                          ino: int, staged: list, new_size: int, mtime: float,
                          nl_version: int | None = None) -> tuple[dict, float]:
        """§5.3: the flush transaction — promote staged chunk writes and
        update metadata size atomically.  staged = [[chunk_off, [stage_ids]]]."""
        self._check_alive()
        self._check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = self.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        plan: dict[str, dict] = {}
        for chunk_off, stage_ids in staged:
            self._plan_add(plan, self.owner(chunk_key(ino, chunk_off)),
                           {"kind": "chunk_promote", "ino": ino,
                            "chunk_off": chunk_off, "stage_ids": stage_ids},
                           [chunk_key(ino, chunk_off)], Cmd.TX_PREPARE_CHUNK)
        self._plan_add(plan, self.node_id,
                       {"kind": "meta_set", "ino": ino,
                        "size": max(new_size, 0), "mtime": mtime,
                        "dirty": True},
                       [meta_key(ino)])
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise FSError(Errno.ECONFLICT, "flush aborted")
        return {"size": new_size}, t

    def coord_unlink(self, start: float, client_id: int, seq: int, parent: int,
                     name: str, ino: int, nl_version: int | None = None
                     ) -> tuple[dict, float]:
        """§5.4: set deleted+dirty on metadata and chunks + unlink from parent;
        the COS delete happens at the next persisting transaction."""
        self._check_alive()
        self._check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = self.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if m.kind == InodeKind.DIR and m.children:
            raise FSError(Errno.ENOTEMPTY, f"ino {ino}")
        plan: dict[str, dict] = {}
        self._plan_add(plan, self.node_id,
                       {"kind": "meta_set", "ino": ino, "deleted": True,
                        "dirty": True, "mtime": start},
                       [meta_key(ino)])
        for coff in self.chunk_offsets(m.size):
            self._plan_add(plan, self.owner(chunk_key(ino, coff)),
                           {"kind": "chunk_delete", "ino": ino,
                            "chunk_off": coff},
                           [chunk_key(ino, coff)], Cmd.TX_PREPARE_CHUNK)
        self._plan_add(plan, self.owner(meta_key(parent)),
                       {"kind": "dir_unlink", "ino": parent, "name": name},
                       [meta_key(parent)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise FSError(Errno.ECONFLICT, "unlink aborted")
        return {"ok": True}, t

    def coord_rename(self, start: float, client_id: int, seq: int,
                     src_parent: int, src_name: str, dst_parent: int,
                     dst_name: str, ino: int, new_cos_key: str | None,
                     nl_version: int | None = None) -> tuple[dict, float]:
        self._check_alive()
        self._check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = self.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if m.kind == InodeKind.DIR:
            # directory rename would need a recursive COS re-key; like other
            # COS wrapper FSs we reject it (documented in DESIGN.md)
            raise FSError(Errno.EINVAL, "directory rename unsupported")
        plan: dict[str, dict] = {}
        op = {"kind": "meta_set", "ino": ino, "dirty": True,
              "cos_key": new_cos_key}
        if m.cos_key:
            op["add_old_key"] = m.cos_key
        self._plan_add(plan, self.node_id, op, [meta_key(ino)])
        self._plan_add(plan, self.owner(meta_key(src_parent)),
                       {"kind": "dir_unlink", "ino": src_parent,
                        "name": src_name},
                       [meta_key(src_parent)], Cmd.TX_PREPARE_DIR)
        self._plan_add(plan, self.owner(meta_key(dst_parent)),
                       {"kind": "dir_link", "ino": dst_parent,
                        "name": dst_name, "child": ino},
                       [meta_key(dst_parent)], Cmd.TX_PREPARE_DIR)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise FSError(Errno.ECONFLICT, "rename aborted")
        return {"ok": True}, t

    def coord_truncate(self, start: float, client_id: int, seq: int, ino: int,
                       new_size: int, mtime: float,
                       nl_version: int | None = None) -> tuple[dict, float]:
        self._check_alive()
        self._check_nl(nl_version)
        self._require_owner(meta_key(ino))
        m = self.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        plan: dict[str, dict] = {}
        self._plan_add(plan, self.node_id,
                       {"kind": "meta_set", "ino": ino, "size": new_size,
                        "mtime": mtime, "dirty": True}, [meta_key(ino)])
        # chunks entirely beyond the new size are deleted; the boundary
        # chunk gets a zero-tail so re-growing never exposes stale bytes
        for coff in self.chunk_offsets(m.size):
            if coff >= new_size:
                self._plan_add(plan, self.owner(chunk_key(ino, coff)),
                               {"kind": "chunk_delete", "ino": ino,
                                "chunk_off": coff},
                               [chunk_key(ino, coff)], Cmd.TX_PREPARE_CHUNK)
            elif coff + self.cfg.chunk_size > new_size:
                frm = new_size - coff
                self._plan_add(plan, self.owner(chunk_key(ino, coff)),
                               {"kind": "chunk_zero_tail", "ino": ino,
                                "chunk_off": coff, "from": frm,
                                "length": self.cfg.chunk_size - frm},
                               [chunk_key(ino, coff)], Cmd.TX_PREPARE_CHUNK)
        res, t = self.coord_execute(start, client_id, seq, plan)
        if res["outcome"] != "commit":
            raise FSError(Errno.ECONFLICT, "truncate aborted")
        return {"ok": True}, t

    # =====================================================================
    # persisting transaction — Fig. 8 (fsync / flush-interval expiry)
    # =====================================================================
    def coord_persist(self, start: float, ino: int, client_id: int, seq: int
                      ) -> tuple[dict, float]:
        """Upload a dirty inode to COS then clear dirty flags transactionally.

        The MPU runs *before* the commit phase so any failure can abort it;
        the MPU-begin key is Raft-logged first so a crashed coordinator can
        abort the orphan upload at recovery (Fig. 8 black dots)."""
        self._check_alive()
        m = self.metas.get(ino)
        if m is None:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if not m.dirty and not m.cos_old_keys:
            return {"outcome": "clean"}, start
        if m.cos_bucket is None or m.cos_key is None:
            return {"outcome": "no-backing"}, start  # not bucket-mapped
        t = start

        if m.deleted:
            # §5.4: deletion propagates as a COS delete
            t = self.cos.delete_object(m.cos_bucket, m.cos_key, start=t)
            t = self._log(Cmd.COS_DELETE_DONE,
                          {"ino": ino, "key": m.cos_key}, t)
            t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
            return {"outcome": "deleted"}, t

        if m.kind == InodeKind.DIR:
            if not m.cos_key:  # bucket-mount root: nothing to upload
                t = self._log(Cmd.DIRTY_CLEARED_META,
                              {"ino": ino, "version": m.version}, t)
                return {"outcome": "dir"}, t
            # directory marker object ("key/" suffix denotes a dir, §3.2)
            t = self.cos.put_object(m.cos_bucket,
                                    m.cos_key.rstrip("/") + "/", b"", start=t)
            t = self._log(Cmd.PUT_OBJECT_DONE, {"ino": ino}, t)
            t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
            return {"outcome": "dir"}, t

        offsets = self.chunk_offsets(m.size)
        if m.size <= self.cfg.chunk_size and \
                self.owner(chunk_key(ino, 0)) == self.node_id:
            # PutObject fast path (§5.2): single participant, single log write
            data, t = self._materialize_local(ino, 0, m, t)
            try:
                t = self.cos.put_object(m.cos_bucket, m.cos_key, data, start=t)
            except CosError:
                return {"outcome": "abort"}, t
            self._crash_at("persist_after_put")
            t = self._log(Cmd.PUT_OBJECT_DONE, {"ino": ino}, t)
            t = self._delete_old_keys(m, t)
            t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
            self._bump("persist_put")
            return {"outcome": "commit"}, t

        # MPU path: begin -> record key -> parallel part adds by chunk owners
        try:
            upload_id, t = self.cos.mpu_begin(m.cos_bucket, m.cos_key, start=t)
        except CosError:
            return {"outcome": "abort"}, t
        t = self._log(Cmd.MPU_BEGIN_RECORDED,
                      {"ino": ino, "upload_id": upload_id,
                       "bucket": m.cos_bucket, "key": m.cos_key}, t)
        self._crash_at("persist_after_mpu_begin")
        ends, ok = [], True
        for part_no, coff in enumerate(offsets, start=1):
            owner = self.owner(chunk_key(ino, coff))
            ln = min(self.cfg.chunk_size, m.size - coff)
            try:
                if owner == self.node_id:
                    data, te = self._materialize_local(ino, coff, m, t)
                    te = self.cos.mpu_add(upload_id, part_no, data, start=te)
                else:
                    _, te = self.router.rpc(
                        self.node_id, owner, "rpc_upload_part", t,
                        nbytes_out=256, ino=ino, chunk_off=coff, length=ln,
                        upload_id=upload_id, part_no=part_no,
                        cos_bucket=m.cos_bucket, cos_key=m.cos_key,
                        file_size=m.size)
                ends.append(te)
            except (SimTimeout, SimCrash, CosError):
                ends.append(self.router.charge_timeout(t))
                ok = False
        t = max(ends) if ends else t
        if not ok:
            t = self.cos.mpu_abort(upload_id, start=t)
            self._bump("persist_abort")
            return {"outcome": "abort"}, t
        try:
            t = self.cos.mpu_commit(upload_id, start=t)
        except CosError:
            t = self.cos.mpu_abort(upload_id, start=t)
            return {"outcome": "abort"}, t
        self._crash_at("persist_after_mpu_commit")
        t = self._log(Cmd.MPU_COMMITTED, {"ino": ino, "upload_id": upload_id}, t)
        t = self._delete_old_keys(m, t)
        t = self._clear_dirty_everywhere(ino, m, t, client_id, seq)
        self._bump("persist_mpu")
        return {"outcome": "commit"}, t

    def _materialize_local(self, ino: int, coff: int, m: InodeMeta,
                           start: float) -> tuple[bytes, float]:
        ln = min(self.cfg.chunk_size, m.size - coff)
        c = self.chunks.get(ino, coff)
        t = start
        if c is None or not c.covered(0, ln):
            if m.cos_key is not None and self.cos.exists(m.cos_bucket, m.cos_key):
                data, t = self.cos.get_object(m.cos_bucket, m.cos_key,
                                              rng=(coff, ln), start=t)
                ref, t = self.raft.append_bulk(data, start=t)
                t = self._log(Cmd.CHUNK_FILL_FROM_COS,
                              {"ino": ino, "chunk_off": coff, "off": 0,
                               "length": len(data), "ref": ref.to_payload()}, t)
                c = self.chunks.get(ino, coff)
        if c is None:
            return b"\0" * ln, t
        t = self.disk.acquire(t, ln)
        return c.materialize(self.raft, ln), t

    def rpc_upload_part(self, start: float, ino: int, chunk_off: int,
                        length: int, upload_id: str, part_no: int,
                        cos_bucket: str, cos_key: str, file_size: int
                        ) -> tuple[dict, float]:
        self._check_alive()
        m = InodeMeta(ino=ino, kind=InodeKind.FILE, size=file_size,
                      cos_bucket=cos_bucket, cos_key=cos_key)
        data, t = self._materialize_local(ino, chunk_off, m, start)
        t = self.cos.mpu_add(upload_id, part_no, data[:length], start=t)
        self._bump("mpu_part")
        return {"ok": True}, t

    def _delete_old_keys(self, m: InodeMeta, start: float) -> float:
        t = start
        for old in m.cos_old_keys:
            if old != m.cos_key:
                t = self.cos.delete_object(m.cos_bucket, old, start=t)
                t = self._log(Cmd.COS_DELETE_DONE, {"ino": m.ino, "key": old}, t)
        return t

    def _clear_dirty_everywhere(self, ino: int, m: InodeMeta, start: float,
                                client_id: int, seq: int) -> float:
        """Commit phase of Fig. 8: clear chunk dirty flags, then metadata.
        Version guards make the clears safe against racing writers (§5.2)."""
        t = start
        ends = []
        for coff in self.chunk_offsets(m.size):
            owner = self.owner(chunk_key(ino, coff))
            if owner == self.node_id:
                c = self.chunks.get(ino, coff)
                if c is not None:
                    ends.append(self._log(Cmd.DIRTY_CLEARED_CHUNK,
                                          {"ino": ino, "chunk_off": coff,
                                           "version": c.version}, t))
            else:
                try:
                    _, te = self.router.rpc(self.node_id, owner,
                                            "rpc_clear_chunk_dirty", t,
                                            ino=ino, chunk_off=coff)
                    ends.append(te)
                except (SimTimeout, SimCrash):
                    ends.append(self.router.charge_timeout(t))
        t = max(ends) if ends else t
        t = self._log(Cmd.DIRTY_CLEARED_META, {"ino": ino,
                                               "version": m.version}, t)
        return t

    def rpc_clear_chunk_dirty(self, start: float, ino: int, chunk_off: int
                              ) -> tuple[dict, float]:
        self._check_alive()
        c = self.chunks.get(ino, chunk_off)
        if c is None:
            return {"ok": True}, start
        t = self._log(Cmd.DIRTY_CLEARED_CHUNK,
                      {"ino": ino, "chunk_off": chunk_off,
                       "version": c.version}, start)
        return {"ok": True}, t

    # =====================================================================
    # migration RPCs (§4.3) — driven by the Cluster reconfiguration txn
    # =====================================================================
    def rpc_set_read_only(self, start: float, value: bool) -> tuple[dict, float]:
        self._check_alive()
        self.read_only = value
        return {"ok": True}, start

    def migration_scan(self, new_ring: HashRing) -> dict:
        """Objects this node owns whose owner changes under `new_ring`.
        Policy (§4.3/§5.5): dirty metadata + dirty chunks migrate; directories
        *always* migrate (the grandparent-overwrite hazard); clean files are
        dropped (refetchable from COS)."""
        out = {"metas": [], "dirs": [], "chunks": [], "drop_metas": [],
               "drop_chunks": []}
        for ino, m in self.metas.inodes.items():
            if self.ring.node_for(meta_key(ino)) != self.node_id:
                continue  # not ours (stale leftover)
            new_owner = new_ring.node_for(meta_key(ino))
            if new_owner == self.node_id:
                continue
            if m.kind == InodeKind.DIR:
                out["dirs"].append((ino, new_owner))
            elif m.dirty:
                out["metas"].append((ino, new_owner))
            else:
                out["drop_metas"].append(ino)
        for (ino, coff), c in self.chunks.chunks.items():
            if self.ring.node_for(chunk_key(ino, coff)) != self.node_id:
                continue
            new_owner = new_ring.node_for(chunk_key(ino, coff))
            if new_owner == self.node_id:
                continue
            if c.dirty:
                out["chunks"].append(((ino, coff), new_owner))
            else:
                out["drop_chunks"].append((ino, coff))
        return out

    def migrate_out(self, scan: dict, start: float) -> tuple[dict, float]:
        """Push scanned objects to their new owners; evict moved + dropped."""
        t = start
        moved = {"metas": 0, "dirs": 0, "chunks": 0, "bytes": 0}
        for ino, dst in scan["dirs"] + scan["metas"]:
            m = self.metas.get(ino)
            if m is None:
                continue
            is_dir = m.kind == InodeKind.DIR
            _, t = self.router.rpc(
                self.node_id, dst, "rpc_migrate_recv_meta", t,
                nbytes_out=len(str(m.to_payload())) + 64,
                meta=m.to_payload(), is_dir=is_dir)
            t = self._log(Cmd.EVICT_META, {"ino": ino}, t)
            moved["dirs" if is_dir else "metas"] += 1
        for (ino, coff), dst in scan["chunks"]:
            c = self.chunks.get(ino, coff)
            if c is None:
                continue
            length = c.local_bytes()
            data = c.materialize(self.raft, max(s.off + s.length for s in
                                                c.base_filled + c.segments)) \
                if (c.base_filled or c.segments) else b""
            _, t = self.router.rpc(
                self.node_id, dst, "rpc_migrate_recv_chunk", t,
                nbytes_out=len(data) + 128,
                ino=ino, chunk_off=coff, version=c.version, dirty=c.dirty,
                deleted=c.deleted, data=data)
            t = self._log(Cmd.EVICT_CHUNK, {"ino": ino, "chunk_off": coff}, t)
            moved["chunks"] += 1
            moved["bytes"] += len(data)
        for ino in scan["drop_metas"]:
            t = self._log(Cmd.EVICT_META, {"ino": ino}, t)
        for (ino, coff) in scan["drop_chunks"]:
            t = self._log(Cmd.EVICT_CHUNK, {"ino": ino, "chunk_off": coff}, t)
        return moved, t

    def rpc_migrate_recv_meta(self, start: float, meta: dict, is_dir: bool
                              ) -> tuple[dict, float]:
        self._check_alive()
        existing = self.metas.get(meta["ino"])
        if existing is not None and existing.kind == InodeKind.DIR and is_dir:
            # merge children: never overwrite a newer dir with an older copy
            # (§4.3 grandparent-overwrite hazard)
            merged = InodeMeta.from_payload(meta)
            merged.children.update(existing.children)
            merged.version = max(merged.version, existing.version)
            meta = merged.to_payload()
        cmd = Cmd.MIGRATE_RECV_DIR if is_dir else Cmd.MIGRATE_RECV_META
        t = self._log(cmd, {"meta": meta}, start)
        return {"ok": True}, t

    def rpc_migrate_recv_chunk(self, start: float, ino: int, chunk_off: int,
                               version: int, dirty: bool, deleted: bool,
                               data: bytes) -> tuple[dict, float]:
        self._check_alive()
        ref, t = self.raft.append_bulk(bytes(data), start=start)
        chunk = ChunkState(ino=ino, chunk_off=chunk_off, version=version,
                           dirty=dirty, deleted=deleted,
                           segments=[Segment(0, len(data), ref)])
        t = self._log(Cmd.MIGRATE_RECV_CHUNK, {"chunk": chunk.to_payload()}, t)
        return {"ok": True}, t

    # =====================================================================
    # maintenance
    # =====================================================================
    def dirty_inventory(self) -> dict:
        return {"metas": self.metas.dirty_inos(),
                "chunks": self.chunks.dirty_keys()}

    def local_bytes(self) -> int:
        return sum(c.local_bytes() for c in self.chunks.chunks.values())

    def compact(self) -> None:
        """Log compaction: rewrite the primary log as one SNAPSHOT entry and
        re-append committed chunk contents with fresh bulk refs.  Requires a
        quiescent server (no prepared transactions)."""
        assert not self.txs.prepared, "compact requires a quiescent server"
        # materialize committed chunk contents before bulk files are truncated
        mat: list[tuple[ChunkState, bytes]] = []
        for c in self.chunks.chunks.values():
            extent = max((s.off + s.length
                          for s in c.base_filled + c.segments), default=0)
            mat.append((c, c.materialize(self.raft, extent) if extent else b""))
        self.raft.compact(self.snapshot_payload())
        for c, data in mat:
            ref, _ = self.raft.append_bulk(data)
            nc = ChunkState(ino=c.ino, chunk_off=c.chunk_off,
                            version=c.version, dirty=c.dirty,
                            deleted=c.deleted,
                            segments=[Segment(0, len(data), ref)] if data
                            else [])
            self.raft.append(Cmd.MIGRATE_RECV_CHUNK,
                             {"chunk": nc.to_payload()})
            self.chunks.chunks[(c.ino, c.chunk_off)] = nc

    def close(self) -> None:
        self.raft.close()
