"""Cache server façade: wiring for the layered subsystems (§3–§5).

One `CacheServer` is the paper's "cluster-local cache" process on one node.
Since the layering refactor it is a *thin façade*: it builds the shared
`ServerState` (state.py) and the four subsystems, exposes the read-side RPCs
(no transaction; §3.3 servers always see committed state), and forwards
everything else:

* `participant.Participant` — WAL `log`/`apply` state machine + the 2PC
  participant RPCs (`rpc_prepare`/`rpc_commit`/`rpc_abort`, §4.4–4.5);
* `coordinator.Coordinator` — 2PC planning and drive (`coord_create`,
  `coord_rename`, …) with the single-node fast path (§4.4);
* `persist.Persister` — Fig. 8's mixed persisting transaction (COS multipart
  upload as an additional participant, dirty-clearing, old-key deletes);
* `migration.Migrator` — ring-change scan/send/recv (§4.3, §5.5).

All remotely callable methods carry an `@rpc_handler` spec; `rpc_handlers()`
hands the typed dispatch table to the router at registration.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

from .coordinator import Coordinator
from .cos import CosStore
from .migration import Migrator
from .net import Router, RpcSpec, collect_handlers, rpc_handler
from .participant import Participant
from .persist import Persister
from .raftlog import RaftLog
from .simclock import HardwareModel, SimClock
from .state import NODELIST_KEY, ServerState  # noqa: F401  (re-export)
from .stores import ChunkState, ChunkTable, MetaTable, Segment
from .types import CHUNK_SIZE_DEFAULT, Cmd, Errno, FSError, InodeKind

__all__ = ["BucketMount", "CacheServer", "NODELIST_KEY", "ServerConfig"]


@dataclass
class ServerConfig:
    chunk_size: int = CHUNK_SIZE_DEFAULT
    flush_interval_s: float = 10.0
    # paper §6.3: prefetch parallelism for COS range reads
    cos_part_parallel: int = 64
    rpc_timeout_s: float = 1.0
    # ---- background write-back pipeline (§5.2, Figs. 12-14) --------------
    # cluster-wide bound on concurrently in-flight coord_persist operations
    flush_inflight: int = 16
    # per-persist bound on concurrently in-flight MPU part uploads
    persist_part_window: int = 16
    # bound on concurrently in-flight migration sends during a ring change
    migrate_inflight: int = 8
    # dirty-page watermarks: above hi, foreground staged writes are stalled
    # and the flusher switches to priority (largest/coldest-first) eviction;
    # 0 disables backpressure entirely
    dirty_hiwater_bytes: int = 256 << 20
    dirty_lowater_bytes: int = 128 << 20
    # base stall per staged write while above the high-watermark
    backpressure_stall_s: float = 0.002
    # ---- metadata fast paths (§4.4-4.5 optimisations) --------------------
    # client-lease TTL on lookup/readdir/getattr replies; <= 0 disables
    # leases (every metadata read goes back to the owner, as before)
    lease_ttl_s: float = 2.0
    # lock acquisition policy: "waitdie" = bounded FIFO wait-die queueing,
    # "voteno" = the paper's all-or-nothing vote-no on any conflict
    lock_mode: str = "waitdie"
    lock_queue_depth: int = 4
    lock_reservation_ttl_s: float = 1.0
    # same-destination RPC coalescing (prepare/commit fan-out, dirty clears,
    # migration sends); False reverts to one envelope per sub-call
    batch_rpcs: bool = True


@dataclass
class BucketMount:
    """One external bucket mounted at /<dirname> (§3.1, Fig. 3a).

    ``backend`` names the storage backend the bucket's objects live on —
    a key into the cluster's backend registry (`Cluster(backends=...)`),
    or the reserved default "cos" (the cluster-wide `CosStore`, resolved
    through the swappable `ServerState.cos`).  See docs/STORAGE.md."""

    dirname: str
    bucket: str
    backend: str = "cos"


class CacheServer:
    def __init__(self, node_id: str, server_uid: int, workdir: str,
                 clock: SimClock, router: Router, cos: CosStore,
                 hw: HardwareModel, cfg: ServerConfig | None = None,
                 buckets: list[BucketMount] | None = None,
                 backends: dict[str, object] | None = None) -> None:
        cfg = cfg or ServerConfig()
        self.buckets = buckets or []
        disk = hw.make_disk(node_id)
        self.state = ServerState(
            node_id=node_id, server_uid=server_uid, workdir=workdir,
            clock=clock, router=router, cos=cos, hw=hw, cfg=cfg,
            backends=backends or {},
            bucket_backends={bm.bucket: bm.backend for bm in self.buckets},
            raft=RaftLog(workdir, clock, disk), disk=disk,
            nic=hw.make_nic(node_id))
        self.state.locks = self.state.make_lock_table()
        # subsystems share the one ServerState
        self.participant = Participant(self.state)
        self.coordinator = Coordinator(self.state, self.participant)
        self.persister = Persister(self.state, self.participant)
        self.migrator = Migrator(self.state, self.participant)
        # forwarded entry points (same bound signatures as before the split)
        self._log = self.participant.log
        self.rpc_prepare = self.participant.rpc_prepare
        self.rpc_commit = self.participant.rpc_commit
        self.rpc_abort = self.participant.rpc_abort
        self.coord_execute = self.coordinator.coord_execute
        self.coord_create = self.coordinator.coord_create
        self.coord_load_dir = self.coordinator.coord_load_dir
        self.coord_flush_write = self.coordinator.coord_flush_write
        self.coord_unlink = self.coordinator.coord_unlink
        self.coord_rename = self.coordinator.coord_rename
        self.coord_truncate = self.coordinator.coord_truncate
        self.coord_persist = self.persister.coord_persist
        self.rpc_upload_part = self.persister.rpc_upload_part
        self.rpc_clear_chunk_dirty = self.persister.rpc_clear_chunk_dirty
        self.rpc_set_read_only = self.migrator.rpc_set_read_only
        self.migration_scan = self.migrator.migration_scan
        self.migrate_out = self.migrator.migrate_out
        self.rpc_migrate_recv_meta = self.migrator.rpc_migrate_recv_meta
        self.rpc_migrate_recv_chunk = self.migrator.rpc_migrate_recv_chunk
        self.arm_crash = self.state.arm_crash
        self.alloc_ino = self.state.alloc_ino
        self.owner = self.state.owner
        self.chunk_offsets = self.state.chunk_offsets
        self.snapshot_payload = self.participant.snapshot_payload
        router.register(self)

    # ---- identity / shared-state views ----------------------------------
    @property
    def node_id(self) -> str: return self.state.node_id

    @property
    def server_uid(self) -> int: return self.state.server_uid

    @property
    def workdir(self) -> str: return self.state.workdir

    @property
    def clock(self) -> SimClock: return self.state.clock

    @property
    def router(self) -> Router: return self.state.router

    @property
    def cos(self) -> CosStore: return self.state.cos

    @cos.setter
    def cos(self, value: CosStore) -> None:
        # tests/benchmarks swap in a shared external store after a cold start
        self.state.cos = value

    @property
    def hw(self) -> HardwareModel: return self.state.hw

    @property
    def cfg(self) -> ServerConfig: return self.state.cfg

    @property
    def raft(self): return self.state.raft

    @property
    def disk(self): return self.state.disk

    @property
    def nic(self): return self.state.nic

    @property
    def metas(self) -> MetaTable: return self.state.metas

    @property
    def chunks(self) -> ChunkTable: return self.state.chunks

    @property
    def locks(self): return self.state.locks

    @property
    def txs(self): return self.state.txs

    @property
    def node_list(self) -> list[str]: return self.state.node_list

    @property
    def node_list_version(self) -> int: return self.state.node_list_version

    @property
    def ring(self): return self.state.ring

    @property
    def stats(self) -> dict: return self.state.stats

    @property
    def alive(self) -> bool: return self.state.alive

    @alive.setter
    def alive(self, value: bool) -> None: self.state.alive = value

    @property
    def read_only(self) -> bool: return self.state.read_only

    @read_only.setter
    def read_only(self, value: bool) -> None: self.state.read_only = value

    def rpc_handlers(self) -> dict[str, tuple[Callable, RpcSpec]]:
        """Typed dispatch table handed to the router at registration."""
        return collect_handlers(self, self.participant, self.coordinator,
                                self.persister, self.migrator)

    # =====================================================================
    # lifecycle
    # =====================================================================
    def crash(self) -> None:
        """Hard-kill: nothing flushed beyond what the WAL already holds."""
        self.state.alive = False

    def restart(self, start: float | None = None) -> float:
        """Replay the WAL and rebuild all state (§3.4)."""
        t0 = self.state.clock.now if start is None else start
        end = self.participant.replay(t0)
        self.state.alive = True
        self.state.read_only = False
        self.state.clock.advance_to(end)
        return end

    def recover_pending(self, start: float) -> float:
        """Post-replay recovery: re-drive in-doubt 2PC decisions, then abort
        any MPU this coordinator began but never committed (Fig. 8: the
        MPU-begin key is logged first precisely so the orphan upload can be
        aborted here)."""
        t = self.coordinator.recover_pending(start)
        return self.persister.recover_orphan_mpus(t)

    # =====================================================================
    # read-side RPCs (no transaction; §3.3 servers always see committed state)
    # =====================================================================
    @rpc_handler()
    def rpc_getattr(self, start: float, ino: int,
                    nl_version: int | None = None,
                    lease_epoch: int | None = None) -> tuple[dict, float]:
        """`lease_epoch` (if given) is a renewal: a stale epoch means some
        mutation committed since the grant and raises `StaleLeaseError` so
        the client drops its cached copy (close-to-open preserved)."""
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        st.check_lease(ino, lease_epoch)
        m = st.metas.get(ino)
        if m is None or m.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        p = m.to_payload()
        p["lease"] = st.lease_grant(ino)
        return p, start

    @rpc_handler()
    def rpc_lookup(self, start: float, parent: int, name: str,
                   nl_version: int | None = None,
                   lease_epoch: int | None = None) -> tuple[dict, float]:
        """Single-name lookup in a parent directory this server owns."""
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        st.check_lease(parent, lease_epoch)
        d = st.metas.get(parent)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"parent {parent}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"parent {parent}")
        child = d.children.get(name)
        if child is None:
            raise FSError(Errno.ENOENT, f"{parent}/{name}")
        return {"ino": child, "lease": st.lease_grant(parent)}, start

    @rpc_handler()
    def rpc_readdir(self, start: float, ino: int,
                    nl_version: int | None = None,
                    lease_epoch: int | None = None) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        st.check_lease(ino, lease_epoch)
        d = st.metas.get(ino)
        if d is None or d.deleted:
            raise FSError(Errno.ENOENT, f"ino {ino}")
        if d.kind != InodeKind.DIR:
            raise FSError(Errno.ENOTDIR, f"ino {ino}")
        return {"children": dict(d.children), "loaded": d.loaded,
                "lease": st.lease_grant(ino)}, start

    @rpc_handler(reply_bytes=512)
    def rpc_read_chunk(self, start: float, ino: int, chunk_off: int, off: int,
                       length: int, cos_bucket: str | None,
                       cos_key: str | None, file_size: int,
                       nl_version: int | None = None) -> tuple[bytes, float]:
        """Read [off, off+length) within one chunk; fills from COS on miss
        (§5.4: each predecessor downloads its own range of the inode)."""
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        be = st.backend_for(cos_bucket)
        c = st.chunks.get(ino, chunk_off)
        cover_len = max(0, min(st.cfg.chunk_size, file_size - chunk_off))
        t = start
        if (c is None or not c.covered(off, min(length, cover_len - off))) \
                and cos_bucket and cos_key and cover_len > 0 \
                and be.exists(cos_bucket, cos_key):
            # cache miss: fetch this chunk's whole range of the object once
            st.bump("cos_fill")
            data, t = be.get_object(cos_bucket, cos_key,
                                    rng=(chunk_off, cover_len), start=t)
            ref, t = st.raft.append_bulk(data, start=t)
            t = self._log(Cmd.CHUNK_FILL_FROM_COS,
                          {"ino": ino, "chunk_off": chunk_off, "off": 0,
                           "length": len(data), "ref": ref.to_payload()}, t)
            c = st.chunks.get(ino, chunk_off)
        if c is None:
            return b"\0" * length, t
        want = min(length, max(cover_len, c.local_bytes()) - off)
        if want <= 0:
            return b"", t
        buf = c.materialize(st.raft, off + want)[off:off + want]
        # local disk read of the materialized bytes
        t = st.disk.acquire(t, len(buf))
        st.bump("chunk_read_bytes", len(buf))
        return buf, t

    @rpc_handler()
    def rpc_nodelist(self, start: float) -> tuple[dict, float]:
        self.state.check_alive()
        return {"nodes": list(self.state.node_list),
                "version": self.state.node_list_version}, start

    # =====================================================================
    # write staging (§5.3: chunk transfer outside the metadata lock)
    # =====================================================================
    @rpc_handler(request_bytes=512)
    def rpc_stage_write(self, start: float, ino: int, chunk_off: int, off: int,
                        data: bytes, stage_id: str,
                        nl_version: int | None = None) -> tuple[dict, float]:
        st = self.state
        st.check_alive()
        st.check_nl(nl_version)
        st.check_writable()
        ref, t = st.raft.append_bulk(bytes(data), start=start)
        t = self._log(Cmd.CHUNK_STAGE,
                      {"ino": ino, "chunk_off": chunk_off, "off": off,
                       "length": len(data), "ref": ref.to_payload(),
                       "stage_id": stage_id}, t)
        st.bump("staged_bytes", len(data))
        # dirty-page backpressure (§5.2): above the high-watermark the reply
        # carries a stall hint that the client honours before issuing more
        # foreground writes, letting the background flusher catch up
        bp = st.backpressure_delay()
        if bp > 0.0:
            st.bump("bp_stalls")
        return {"ok": True, "bp_delay": bp}, t

    # =====================================================================
    # maintenance
    # =====================================================================
    def dirty_inventory(self) -> dict:
        return {"metas": self.state.metas.dirty_inos(),
                "chunks": self.state.chunks.dirty_keys()}

    def local_bytes(self) -> int:
        return sum(c.local_bytes() for c in self.state.chunks.chunks.values())

    def compact(self) -> None:
        """Log compaction: rewrite the primary log as one SNAPSHOT entry and
        re-append committed chunk contents with fresh bulk refs.  Requires a
        quiescent server (no prepared transactions)."""
        st = self.state
        assert not st.txs.prepared, "compact requires a quiescent server"
        # materialize committed chunk contents before bulk files are truncated
        mat: list[tuple[ChunkState, bytes]] = []
        for c in st.chunks.chunks.values():
            extent = max((s.off + s.length
                          for s in c.base_filled + c.segments), default=0)
            mat.append((c, c.materialize(st.raft, extent) if extent else b""))
        st.raft.compact(self.snapshot_payload())
        for c, data in mat:
            ref, _ = st.raft.append_bulk(data)
            nc = ChunkState(ino=c.ino, chunk_off=c.chunk_off,
                            version=c.version, dirty=c.dirty,
                            deleted=c.deleted,
                            segments=[Segment(0, len(data), ref)] if data
                            else [])
            st.raft.append(Cmd.MIGRATE_RECV_CHUNK,
                           {"chunk": nc.to_payload()})
            st.chunks.chunks[(c.ino, c.chunk_off)] = nc

    def close(self) -> None:
        self.state.raft.close()
