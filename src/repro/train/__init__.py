from .steps import TrainState, make_decode_step, make_prefill_step, \
    make_train_step, train_state_init

__all__ = ["TrainState", "make_decode_step", "make_prefill_step",
           "make_train_step", "train_state_init"]
