"""Train / prefill / decode step factories — the functions the launcher
jits with explicit in/out shardings and the dry-run lowers.

`train_step(state, batch)` computes loss + grads (bf16 compute), applies
AdamW on fp32 masters (ZeRO-1 sharded), and returns the new state with bf16
params re-cast from the masters.  `decode_step` is the serve_step that the
decode-shape dry-run cells lower (one new token against a full KV cache).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import Model
from ..optim import AdamWConfig, adamw_init, adamw_update


@dataclass
class TrainState:
    params: Any
    opt: dict
    step: jax.Array

    def tree_flatten(self):  # pragma: no cover - registered below
        return (self.params, self.opt, self.step), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt, s.step), None),
    lambda _, xs: TrainState(params=xs[0], opt=xs[1], step=xs[2]))


def train_state_init(model: Model, key, max_seq: int = 4096
                     ) -> tuple[TrainState, dict]:
    params, spec = model.init(key, max_seq=max_seq)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32)), spec


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    grad_shardings=None, accum_steps: int = 1,
                    reduce_dtype: str | None = None
                    ) -> Callable[[TrainState, dict], tuple[TrainState,
                                                            dict]]:
    """grad_shardings: optional NamedSharding tree (the ZeRO-1 optimizer
    shardings).  Constraining the gradients to the optimizer-shard layout
    makes XLA lower the cross-data reduction as reduce-scatter into the
    shards instead of a full all-reduce (§Perf#4).  accum_steps > 1 splits
    the global batch into microbatches (§Perf#7: activation memory).
    reduce_dtype="bfloat16" compresses gradients before the cross-data
    reduction (halves DCN/ICI gradient traffic; the fp32 AdamW update is
    unchanged — standard large-scale trade-off)."""
    def grad_fn(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def train_step(state: TrainState, batch: dict
                   ) -> tuple[TrainState, dict]:
        if accum_steps <= 1:
            loss, grads = grad_fn(state.params, batch)
        else:
            # gradient accumulation: activation memory scales with the
            # microbatch while the optimizer sees the full global batch
            micro = jax.tree.map(
                lambda x: x.reshape((accum_steps, x.shape[0] // accum_steps)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                loss_sum, gsum = carry
                l, g = grad_fn(state.params, mb)
                return (loss_sum + l,
                        jax.tree.map(jnp.add, gsum, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        if reduce_dtype is not None:
            grads = jax.tree.map(
                lambda g: g.astype(jnp.dtype(reduce_dtype)), grads)
        if grad_shardings is not None:
            grads = jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(g, s),
                grads, grad_shardings)
        new_master, new_opt, metrics = adamw_update(opt_cfg, grads,
                                                    state.opt)
        # recast masters to the compute dtypes of the live params
        new_params = jax.tree.map(lambda m, p: m.astype(p.dtype),
                                  new_master, state.params)
        metrics = dict(metrics, loss=loss)
        return TrainState(params=new_params, opt=new_opt,
                          step=state.step + 1), metrics
    return train_step


def make_prefill_step(model: Model) -> Callable[[Any, dict], jax.Array]:
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, token, cache, cache_len):
        return model.decode(params, token, cache, cache_len)
    return decode_step
