"""Production mesh definitions.

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax import to obtain 512 placeholder host devices.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — DP over
pod×data (DCN across pods), TP over model (ICI within pod).
"""

from __future__ import annotations

import jax


def set_mesh(mesh: jax.sharding.Mesh):
    """Version-portable `with set_mesh(mesh): ...` context.

    `jax.set_mesh` appeared in jax 0.6 (and `jax.sharding.use_mesh` briefly
    before it); on 0.4.x/0.5.x neither exists and the `Mesh` object itself is
    the context manager that installs the physical mesh for jit/shard_map.
    All three behave identically for our dry-run/calibration lowering, which
    only needs the mesh active while tracing."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many devices exist (tests, examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
