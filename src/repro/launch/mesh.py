"""Production mesh definitions.

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS before any jax import to obtain 512 placeholder host devices.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — DP over
pod×data (DCN across pods), TP over model (ICI within pod).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many devices exist (tests, examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
