"""Serving driver: load a checkpoint through the cache tiers and serve
batched greedy generation (§6.3's Triton-startup scenario).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --reduced
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import ARCH_IDS, get_config, get_reduced
from ..models import build_model
from ..serving import ModelStore, ServingEngine
from ..train import train_state_init
from .train import build_cache


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--workdir", default="/tmp/objcache-serve")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family == "audio":
        print("serve driver targets LM decode; whisper path exercised in "
              "tests")
    model = build_model(cfg)
    cluster, fs = build_cache(args.workdir)

    # publish a "model repository" into COS via a training-state save
    state, _ = train_state_init(model, jax.random.PRNGKey(0), max_seq=64)
    ckpt = CheckpointManager(fs, "/train/models/demo")
    ckpt.save(0, state.params, durable=True)

    # a fresh replica loads through the cache tiers (cold -> warm)
    t0 = cluster.clock.now
    store = ModelStore(fs, "/train/models/demo")
    params, nbytes = store.load(0, like=state.params)
    print(f"model load: {nbytes / 1e6:.1f} MB in {cluster.clock.now - t0:.3f}"
          f" virtual-s (cold)")
    t0 = cluster.clock.now
    params, _ = store.load(0, like=state.params)
    print(f"model load: warm tier in {cluster.clock.now - t0:.3f} virtual-s")

    engine = ServingEngine(model, params, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=rng.integers(4, 12),
                            dtype=np.int32) for _ in range(args.batch)]
    w0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    print(f"generated {args.batch} x {args.max_new} tokens in "
          f"{time.time() - w0:.2f}s wall")
    for i, o in enumerate(outs):
        print(f"  req{i}: {o}")
    cluster.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
