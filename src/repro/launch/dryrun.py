import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without real hardware:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Per cell it records (reports/dryrun/<arch>__<shape>__<mesh>.json):

* memory_analysis()  — per-device argument/output/temp bytes (fits HBM?);
* cost_analysis()    — HLO FLOPs / bytes accessed (roofline numerators);
* collective bytes   — summed operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute parsed from the
  post-SPMD compiled HLO (cost_analysis does not expose these).

NOTE the XLA_FLAGS line above MUST precede any jax import — jax locks the
device count at first init.  Do not set it globally: smoke tests and
benches must see one device.
"""

import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, SHAPES, get_config
from ..models import build_model
from ..optim import AdamWConfig
from ..train.steps import make_decode_step, make_train_step
from .mesh import make_production_mesh, set_mesh
from .specs import (abstract_state, input_specs, shardings_for_batch,
                    shardings_for_decode, shardings_for_state)
from ..parallel import default_rules

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# per-arch launch tuning for the train shape (found in §Perf iteration;
# accumulation bounds activation memory, EP fits jamba's 16 experts to the
# 16-way model axis exactly)
TRAIN_TUNING: dict[str, dict] = {
    "jamba-v0.1-52b": {"accum_steps": 8, "expert_partition": "expert"},
    "qwen2.5-14b": {"accum_steps": 2},
    "granite-8b": {"accum_steps": 2},
    "olmoe-1b-7b": {"accum_steps": 4},
    "qwen2-moe-a2.7b": {"accum_steps": 2},
    "h2o-danube-3-4b": {"accum_steps": 2},
    "whisper-tiny": {"accum_steps": 16},
}


def cost_analysis_dict(compiled) -> dict:
    """Version-portable `compiled.cost_analysis()`: jax <= 0.4.x returns a
    one-element list of dicts (per program), newer jax returns the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in post-SPMD HLO."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            body = ls.split(" = ", 1)
            if len(body) != 2:
                continue
            rhs = body[1]
            op = None
            for c in _COLLECTIVES:
                # match "... all-reduce(" or "all-reduce-start("
                if re.search(rf"\b{c}(-start)?\(", rhs):
                    op = c
                    break
            if op is None:
                continue
            # output shape(s): leading "f32[a,b]" possibly tuple "(f32[..)"
            nbytes = 0
            head = rhs.split(op)[0]
            for m in _SHAPE_RE.finditer(head):
                dt, dims = m.group(1), m.group(2)
                if dt not in _DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * _DTYPE_BYTES[dt]
            out[op] += nbytes
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               expert_partition: str = "ff", remat: str | None = None,
               scan_layers: bool | None = None, accum_steps: int = 1):
    cfg = get_config(arch)
    if remat is not None:
        cfg = cfg.with_(remat=remat)
    if scan_layers is not None:
        cfg = cfg.with_(scan_layers=scan_layers)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return None  # skipped per DESIGN.md §Arch-applicability
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh, expert_partition=expert_partition)
    from ..parallel import ctx
    ctx.set_from_mesh(mesh, rules)
    specs = input_specs(cfg, shape, model)

    max_seq = shape.seq_len
    with set_mesh(mesh):
        if shape.kind == "train":
            state, spec = abstract_state(model, max_seq, with_opt=True)
            state_sh = shardings_for_state(state, spec, mesh, rules)
            batch_sh = shardings_for_batch(specs, mesh, rules)
            step = make_train_step(model, AdamWConfig(),
                                   grad_shardings=state_sh.opt["m"],
                                   accum_steps=accum_steps)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
            ).lower(state, specs)
        elif shape.kind == "prefill":
            params, spec = abstract_state(model, max_seq, with_opt=False)
            from ..parallel import param_shardings
            p_sh = param_shardings(spec, params, mesh, rules)
            batch_sh = shardings_for_batch(specs, mesh, rules)
            lowered = jax.jit(
                lambda p, b: model.prefill(p, b),
                in_shardings=(p_sh, batch_sh),
            ).lower(params, specs)
        else:  # decode
            params, spec = abstract_state(model, max_seq, with_opt=False)
            from ..parallel import param_shardings
            p_sh = param_shardings(spec, params, mesh, rules)
            io_sh = shardings_for_decode(specs, mesh, rules)
            step = make_decode_step(model)
            lowered = jax.jit(
                step,
                in_shardings=(p_sh, io_sh["token"], io_sh["cache"],
                              io_sh["cache_len"]),
                out_shardings=(None, io_sh["cache"]),
            ).lower(params, specs["token"], specs["cache"],
                    specs["cache_len"])
    return lowered, cfg, shape, mesh


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, **kw) -> dict | None:
    t0 = time.time()
    if SHAPES[shape_name].kind == "train":
        for k, v in TRAIN_TUNING.get(arch, {}).items():
            kw.setdefault(k, v)
    out = lower_cell(arch, shape_name, multi_pod, **kw)
    if out is None:
        print(f"SKIP  {arch} × {shape_name} (full attention at 500k)")
        return None
    lowered, cfg, shape, mesh = out
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    n_dev = mesh.devices.size
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": int(n_dev),
        "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "compile_s": round(t_compile, 1),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
        "per_device": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
            "flops": flops,
            "bytes_accessed": bytes_acc,
            "collective_bytes": coll,
        },
    }
    if save:
        os.makedirs(REPORT_DIR, exist_ok=True)
        path = os.path.join(
            REPORT_DIR, f"{arch}__{shape_name}__{rec['mesh']}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    gb = 1 << 30
    print(f"OK    {arch} × {shape_name} × {rec['mesh']}  "
          f"compile={t_compile:6.1f}s  "
          f"args={mem.argument_size_in_bytes / gb:7.2f}GiB/dev  "
          f"temp={mem.temp_size_in_bytes / gb:7.2f}GiB/dev  "
          f"flops/dev={flops:.3e}  coll={coll['total'] / gb:.3f}GiB")
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--expert-partition", default="ff",
                    choices=("ff", "expert"))
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells: list[tuple[str, str]] = []
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape in cells:
        for mp in meshes:
            try:
                run_cell(arch, shape, mp,
                         expert_partition=args.expert_partition)
            except Exception as e:  # noqa: BLE001 - report and continue
                failures.append((arch, shape, mp, repr(e)[:200]))
                print(f"FAIL  {arch} × {shape} × "
                      f"{'2x16x16' if mp else '16x16'}: {e!r}"[:300])
    if failures:
        print(f"\n{len(failures)} failures")
        return 1
    print("\nALL CELLS PASSED")
    return 0


if __name__ == "__main__":
    sys.exit(main())
