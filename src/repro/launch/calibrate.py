import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""FLOPs/bytes/collective calibration for scanned models.

XLA's cost_analysis counts a while-loop body ONCE, so the scanned dry-run
underreports per-step cost by ~n_periods×.  We recover the true totals by
lowering the model UNROLLED at depths of exactly 1 and 2 periods:

    F(k) = f_outside + k·f_body   ⇒   f_body = F(2) − F(1)

and correcting the full-depth record:

    corrected = F(1) + (n_periods − 1)·f_body

applied to flops, bytes-accessed and per-collective bytes alike.  Writes
reports/calibration/<arch>__<shape>.json; benchmarks.roofline consumes them.

    PYTHONPATH=src python -m repro.launch.calibrate --all
"""

import argparse
import json
import sys

from ..configs import ARCH_IDS, SHAPES, get_config
from ..models.lm import n_periods, period_length
from .dryrun import collective_bytes, cost_analysis_dict, lower_cell
from .mesh import set_mesh

CAL_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "reports", "calibration")


def _measure(arch: str, shape_name: str, k_periods: int) -> dict | None:
    cfg = get_config(arch)
    plen = period_length(cfg) if cfg.family != "audio" else 1
    depth = k_periods * plen
    kw = {}
    if cfg.family == "audio":
        # scale encoder and decoder together
        cfg_small = cfg.with_(n_layers=depth, enc_layers=depth)
    else:
        cfg_small = cfg.with_(n_layers=depth)
    # monkey-patch the registry entry via direct lowering on the small cfg
    from ..models import build_model
    from .dryrun import make_production_mesh
    from .specs import (abstract_state, input_specs, shardings_for_batch,
                        shardings_for_decode, shardings_for_state)
    from ..parallel import default_rules, param_shardings
    from ..optim import AdamWConfig
    from ..train.steps import make_decode_step, make_train_step
    import jax

    cfg_small = cfg_small.with_(scan_layers=False)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return None
    model = build_model(cfg_small)
    mesh = make_production_mesh(multi_pod=False)
    rules = default_rules(mesh)
    from ..parallel import ctx
    ctx.set_from_mesh(mesh, rules)
    specs = input_specs(cfg_small, shape, model)
    with set_mesh(mesh):
        if shape.kind == "train":
            state, spec = abstract_state(model, shape.seq_len, with_opt=True)
            state_sh = shardings_for_state(state, spec, mesh, rules)
            batch_sh = shardings_for_batch(specs, mesh, rules)
            step = make_train_step(model, AdamWConfig(),
                                   grad_shardings=state_sh.opt["m"])
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None)
                              ).lower(state, specs)
        elif shape.kind == "prefill":
            params, spec = abstract_state(model, shape.seq_len,
                                          with_opt=False)
            p_sh = param_shardings(spec, params, mesh, rules)
            batch_sh = shardings_for_batch(specs, mesh, rules)
            lowered = jax.jit(lambda p, b: model.prefill(p, b),
                              in_shardings=(p_sh, batch_sh)
                              ).lower(params, specs)
        else:
            params, spec = abstract_state(model, shape.seq_len,
                                          with_opt=False)
            p_sh = param_shardings(spec, params, mesh, rules)
            io_sh = shardings_for_decode(specs, mesh, rules)
            step = make_decode_step(model)
            lowered = jax.jit(step,
                              in_shardings=(p_sh, io_sh["token"],
                                            io_sh["cache"],
                                            io_sh["cache_len"]),
                              out_shardings=(None, io_sh["cache"]),
                              ).lower(params, specs["token"],
                                      specs["cache"], specs["cache_len"])
    compiled = lowered.compile()
    cost = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "collective": coll}


def calibrate(arch: str, shape_name: str) -> dict | None:
    cfg = get_config(arch)
    if SHAPES[shape_name].name == "long_500k" \
            and not cfg.supports_long_context():
        return None
    nper = n_periods(cfg) if cfg.family != "audio" else cfg.n_layers
    f1 = _measure(arch, shape_name, 1)
    f2 = _measure(arch, shape_name, 2)
    if f1 is None or f2 is None:
        return None
    body = {
        "flops": f2["flops"] - f1["flops"],
        "bytes": f2["bytes"] - f1["bytes"],
        "collective": {k: f2["collective"][k] - f1["collective"][k]
                       for k in f1["collective"]},
    }
    corrected = {
        "flops": f1["flops"] + (nper - 1) * body["flops"],
        "bytes": f1["bytes"] + (nper - 1) * body["bytes"],
        "collective": {k: f1["collective"][k]
                       + (nper - 1) * body["collective"][k]
                       for k in f1["collective"]},
    }
    rec = {"arch": arch, "shape": shape_name, "n_periods": nper,
           "one_period": f1, "body": body, "corrected": corrected}
    os.makedirs(CAL_DIR, exist_ok=True)
    with open(os.path.join(CAL_DIR, f"{arch}__{shape_name}.json"),
              "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()
    cells = [(a, s) for a in ARCH_IDS for s in SHAPES] if args.all \
        else [(args.arch, args.shape)]
    fails = 0
    for arch, shape in cells:
        try:
            rec = calibrate(arch, shape)
            if rec is None:
                print(f"SKIP {arch} × {shape}")
                continue
            print(f"OK   {arch} × {shape}  corrected flops/dev = "
                  f"{rec['corrected']['flops']:.3e}  coll/dev = "
                  f"{rec['corrected']['collective']['total'] / 2**30:.2f} GiB")
        except Exception as e:  # noqa: BLE001
            fails += 1
            print(f"FAIL {arch} × {shape}: {e!r}"[:200])
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
