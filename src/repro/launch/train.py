"""End-to-end training driver with objcache-backed data + checkpointing.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-every 20

Runs on whatever devices exist (CPU in this container) with a debug mesh;
the production mesh path is exercised by the dry-run.  Demonstrates the
paper's loop: stream tokens through the cache FS, checkpoint transactionally
to cluster-local storage, write back to COS asynchronously, and resume from
the latest manifest after a (simulated) failure.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import ARCH_IDS, get_config, get_reduced
from ..core import (BucketMount, ClientConfig, Cluster, ObjcacheClient,
                    ObjcacheFS, ServerConfig)
from ..data import TokenPipeline, synth_corpus_to_cos
from ..models import build_model
from ..optim import AdamWConfig
from ..train import make_train_step, train_state_init


def build_cache(workdir: str, chunk_mb: int = 1, nodes: int = 2
                ) -> tuple[Cluster, ObjcacheFS]:
    cfg = ServerConfig(chunk_size=chunk_mb << 20)
    cluster = Cluster(workdir, [BucketMount("train", "train")], cfg=cfg)
    cluster.start(nodes)
    client = ObjcacheClient(cluster.router, cluster.clock,
                            cluster.node_list()[0],
                            ClientConfig(consistency="weak"),
                            chunk_size=cfg.chunk_size)
    return cluster, ObjcacheFS(client)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--workdir", default="/tmp/objcache-train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    cluster, fs = build_cache(args.workdir)

    # synthetic corpus straight into COS; the pipeline reads it through the
    # cache (first epoch = cold tier, later epochs = cluster/node tier)
    synth_corpus_to_cos(cluster.cos, "train", "corpus", n_shards=4,
                        tokens_per_shard=args.batch * (args.seq + 1) * 8,
                        vocab=cfg.vocab)
    pipe = TokenPipeline(fs, "/train/corpus", batch=args.batch,
                         seq_len=args.seq)
    ckpt = CheckpointManager(fs, "/train/ckpt")

    state, _spec = train_state_init(model, jax.random.PRNGKey(0),
                                    max_seq=args.seq)
    start_step = 0
    if args.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, like=state)
            start_step = latest
            print(f"resumed from step {latest}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    it = iter(pipe.batches(epoch=0))
    epoch = 0
    t0 = time.time()
    for step in range(start_step, args.steps):
        try:
            batch = next(it)
        except StopIteration:
            epoch += 1
            it = iter(pipe.batches(epoch=epoch))
            batch = next(it)
        if cfg.frontend is not None:
            from ..models.lm import frontend_dim
            nf = cfg.enc_seq if cfg.family == "audio" \
                else cfg.n_frontend_tokens
            batch["frontend"] = np.zeros(
                (args.batch, nf, frontend_dim(cfg)), np.float32)
        state, metrics = step_fn(state, batch)
        if (step + 1) % 10 == 0 or step == start_step:
            print(f"step {step + 1:5d}  loss {float(metrics['loss']):8.4f}  "
                  f"gnorm {float(metrics['grad_norm']):8.3f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"wall {time.time() - t0:6.1f}s")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
            # async write-back: uploads overlap the next steps (Fig. 12)
            cluster.tick_flush(max_inodes=8)
    cluster.drain_dirty()
    print(f"done; dirty remaining: {cluster.dirty_counts()}")
    cluster.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
