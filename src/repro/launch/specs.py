"""ShapeDtypeStruct stand-ins + sharding trees for every (arch × shape) cell.

`input_specs(cfg, shape)` returns the abstract inputs the step function of
that cell consumes — weak-type-correct, shardable, no device allocation:

* train:   {tokens (B, S) i32, labels (B, S) i32 [, frontend]}
* prefill: {tokens (B, S) i32 [, frontend]}
* decode:  (token (B, 1) i32, cache pytree, cache_len scalar i32)

`abstract_state` eval-shapes the model init (+ optimizer) without
allocating, and `shardings_for_*` resolve the in/out sharding trees.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import Model, build_model
from ..models.config import ArchConfig, ShapeConfig
from ..models.lm import frontend_dim
from ..optim import adamw_init
from ..parallel import (ShardingRules, batch_pspec, cache_pspec,
                        default_rules, param_shardings, zero1_shardings)
from ..train.steps import TrainState


def text_len(cfg: ArchConfig, shape: ShapeConfig) -> int:
    """Frontend stubs occupy positions: text length excludes them so the
    total sequence matches the cell's seq_len."""
    if cfg.frontend == "patch":
        return shape.seq_len - cfg.n_frontend_tokens
    return shape.seq_len


def input_specs(cfg: ArchConfig, shape: ShapeConfig, model: Model | None = None
                ) -> dict:
    model = model or build_model(cfg)
    b = shape.global_batch
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        s = text_len(cfg, shape)
        specs = {"tokens": sd((b, s), jnp.int32),
                 "labels": sd((b, s), jnp.int32)}
        if cfg.frontend is not None:
            nf = cfg.enc_seq if cfg.family == "audio" \
                else cfg.n_frontend_tokens
            specs["frontend"] = sd((b, nf, frontend_dim(cfg)), jnp.float32)
        return specs
    if shape.kind == "prefill":
        s = text_len(cfg, shape)
        specs = {"tokens": sd((b, s), jnp.int32)}
        if cfg.frontend is not None:
            nf = cfg.enc_seq if cfg.family == "audio" \
                else cfg.n_frontend_tokens
            specs["frontend"] = sd((b, nf, frontend_dim(cfg)), jnp.float32)
        return specs
    # decode: one new token against a cache of seq_len
    return {
        "token": sd((b, 1), jnp.int32),
        "cache": model.cache_spec(b, shape.seq_len),
        "cache_len": sd((), jnp.int32),
    }


def abstract_state(model: Model, max_seq: int, with_opt: bool = True
                   ) -> tuple[TrainState | dict, dict]:
    """Eval-shape the params (+ optimizer) — no allocation.  Returns
    (abstract state or params, logical spec tree)."""
    holder = {}

    def init_only(key):
        p, s = model.init(key, max_seq=max_seq)
        holder["spec"] = s
        return p

    params = jax.eval_shape(init_only, jax.random.PRNGKey(0))
    spec = holder["spec"]
    if not with_opt:
        return params, spec
    opt = jax.eval_shape(adamw_init, params)
    state = TrainState(params=params, opt=opt,
                       step=jax.ShapeDtypeStruct((), jnp.int32))
    return state, spec


def shardings_for_state(state: TrainState, spec, mesh: Mesh,
                        rules: ShardingRules) -> TrainState:
    p_sh = param_shardings(spec, state.params, mesh, rules)
    z_sh = lambda tree: zero1_shardings(spec, tree, mesh, rules)
    opt_sh = {
        "master": z_sh(state.opt["master"]),
        "m": z_sh(state.opt["m"]),
        "v": z_sh(state.opt["v"]),
        "step": NamedSharding(mesh, P()),
    }
    return TrainState(params=p_sh, opt=opt_sh,
                      step=NamedSharding(mesh, P()))


def shardings_for_batch(specs: dict, mesh: Mesh, rules: ShardingRules
                        ) -> dict:
    return {k: NamedSharding(mesh, batch_pspec(v.shape, mesh, rules))
            for k, v in specs.items()}


def shardings_for_decode(specs: dict, mesh: Mesh, rules: ShardingRules
                         ) -> dict:
    def one(path_leaf):
        shp = path_leaf.shape
        if len(shp) >= 4:   # cache leaves (L, B, H, S, D) / (L, B, ...)
            return NamedSharding(mesh, cache_pspec(shp, mesh, rules))
        if len(shp) == 2:   # token (B, 1)
            return NamedSharding(mesh, batch_pspec(shp, mesh, rules))
        return NamedSharding(mesh, P())
    return {
        "token": one(specs["token"]),
        "cache": jax.tree.map(one, specs["cache"]),
        "cache_len": NamedSharding(mesh, P()),
    }
