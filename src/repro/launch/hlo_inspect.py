import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Profile substitute: list the largest collectives / ops in a compiled cell.

    PYTHONPATH=src python -m repro.launch.hlo_inspect --arch granite-8b \
        --shape decode_32k [--top 15]
"""

import argparse
import re
import sys

from ..configs import ARCH_IDS, SHAPES
from .dryrun import _DTYPE_BYTES, _SHAPE_RE, lower_cell

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")


def shape_bytes(text: str) -> int:
    n = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        k = 1
        for d in dims.split(","):
            if d:
                k *= int(d)
        n += k * _DTYPE_BYTES[dt]
    return n


def inspect(hlo: str, top: int = 15) -> list[tuple[int, str, str]]:
    rows = []
    for line in hlo.splitlines():
        ls = line.strip()
        if not (ls.startswith("%") or ls.startswith("ROOT")):
            continue
        body = ls.split(" = ", 1)
        if len(body) != 2:
            continue
        m = _COLL_RE.search(body[1])
        if not m:
            continue
        out_bytes = shape_bytes(body[1].split(m.group(1))[0])
        rows.append((out_bytes, m.group(1), ls[:240]))
    rows.sort(reverse=True)
    return rows[:top]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=tuple(SHAPES), required=True)
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--scan", action="store_true", default=True)
    args = ap.parse_args()
    out = lower_cell(args.arch, args.shape, multi_pod=False)
    lowered = out[0]
    compiled = lowered.compile()
    hlo = compiled.as_text()
    for nbytes, kind, line in inspect(hlo, args.top):
        print(f"{nbytes / 2**20:10.1f} MiB  {kind:18s} {line[:170]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
