from .sharding import (ShardingRules, batch_pspec, cache_pspec,
                       default_rules, param_shardings, pspec_for,
                       zero1_shardings)

__all__ = ["ShardingRules", "batch_pspec", "cache_pspec", "default_rules",
           "param_shardings", "pspec_for", "zero1_shardings"]
