"""Divisibility-aware logical-axis sharding.

Model parameters carry *logical* axis names (produced by init alongside the
params); this module resolves them to mesh `PartitionSpec`s:

* a logical axis maps to mesh axes only when the dimension size is divisible
  by the product of those mesh-axis sizes — otherwise the dimension is
  replicated (recorded per-tensor; e.g. whisper's 6 heads on 16-way TP);
* `zero1_shardings` additionally shards optimizer state over the data axes
  (ZeRO-1): the first dimension not already sharded whose size divides the
  data-axis product picks up ("pod","data") — XLA then reduce-scatters
  gradients into the shards and all-gathers updated params;
* `cache_pspec` shards decode caches on batch when divisible, falling back
  to the sequence dimension for the long-context single-request shape.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> tuple of mesh axis names."""

    rules: dict = field(default_factory=dict)
    batch_axes: tuple = ("data",)
    data_axes: tuple = ("data",)     # ZeRO-1 / batch sharding axes
    model_axes: tuple = ("model",)

    def axes_for(self, logical: str | None) -> tuple:
        if logical is None:
            return ()
        return tuple(self.rules.get(logical, ()))


def default_rules(mesh: Mesh, expert_partition: str = "ff") -> ShardingRules:
    multi_pod = "pod" in mesh.axis_names
    batch = ("pod", "data") if multi_pod else ("data",)
    rules = {
        "batch": batch,
        "vocab": ("model",),
        "ff": ("model",),
        "expert_ff": ("model",),
        "q_proj": ("model",),
        "kv_proj": ("model",),
        "heads": ("model",),
        "embed": (),          # replicated: the residual dimension
        "layers": (),
        "expert": ("model",) if expert_partition == "expert" else (),
    }
    if expert_partition == "expert":
        rules["expert_ff"] = ()
    return ShardingRules(rules=rules, batch_axes=batch, data_axes=batch,
                         model_axes=("model",))


def _axis_size(mesh: Mesh, axes: tuple) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def pspec_for(logical: tuple, shape: tuple, mesh: Mesh,
              rules: ShardingRules) -> P:
    """Resolve one parameter's logical spec to a PartitionSpec, dropping
    (replicating) any axis whose size does not divide the mesh extent."""
    assert len(logical) == len(shape), (logical, shape)
    out = []
    for name, dim in zip(logical, shape):
        axes = rules.axes_for(name)
        if axes and dim % _axis_size(mesh, axes) == 0:
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_shardings(spec_tree, params_tree, mesh: Mesh,
                    rules: ShardingRules):
    """NamedSharding tree matching params_tree (specs are tuples of logical
    names; params may be arrays or ShapeDtypeStructs)."""
    def resolve(spec, p):
        return NamedSharding(mesh, pspec_for(tuple(spec), p.shape, mesh,
                                             rules))
    return jax.tree.map(resolve, spec_tree, params_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def zero1_pspec(logical: tuple, shape: tuple, mesh: Mesh,
                rules: ShardingRules) -> P:
    """Param sharding plus data-axis sharding on the first still-replicated
    divisible dimension (ZeRO-1)."""
    dsize = _axis_size(mesh, rules.data_axes)
    base = pspec_for(tuple(logical), shape, mesh, rules)
    parts = list(base) + [None] * (len(shape) - len(base))
    for i, dim in enumerate(shape):
        if parts[i] is None and dim % dsize == 0 and dim >= dsize:
            parts[i] = (rules.data_axes if len(rules.data_axes) > 1
                        else rules.data_axes[0])
            break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def zero1_shardings(spec_tree, params_tree, mesh: Mesh,
                    rules: ShardingRules):
    """Optimizer-state NamedSharding tree (see zero1_pspec)."""
    def resolve(spec, p):
        return NamedSharding(mesh, zero1_pspec(tuple(spec), p.shape, mesh,
                                               rules))
    return jax.tree.map(resolve, spec_tree, params_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_pspec(shape: tuple, mesh: Mesh, rules: ShardingRules) -> P:
    """Input batches: shard dim 0 on the batch axes when divisible."""
    if shape and shape[0] % _axis_size(mesh, rules.batch_axes) == 0:
        ax = rules.batch_axes
        return P(ax if len(ax) > 1 else ax[0])
    return P()


def cache_pspec(shape: tuple, mesh: Mesh, rules: ShardingRules,
                batch_dim: int = 1, seq_dim: int = 3) -> P:
    """Decode caches (L, B, H, S, D): batch shards on the data axes when
    divisible (falling back to the sequence dimension for single-request
    long-context), and the sequence dimension additionally shards on the
    model axes — a replicated 32k×many-layer KV cache would not fit HBM
    (§Perf#2: 37 GiB/dev replicated vs 2.4 GiB/dev 2D-sharded)."""
    bsz = _axis_size(mesh, rules.batch_axes)
    msz = _axis_size(mesh, rules.model_axes)
    parts: list = [None] * len(shape)
    bax = rules.batch_axes if len(rules.batch_axes) > 1 \
        else rules.batch_axes[0]
    max_ = rules.model_axes if len(rules.model_axes) > 1 \
        else rules.model_axes[0]
    if batch_dim < len(shape) and shape[batch_dim] % bsz == 0:
        parts[batch_dim] = bax
    elif seq_dim < len(shape) and shape[seq_dim] % (bsz * msz) == 0:
        # single-request long context: split the sequence over everything
        parts[seq_dim] = (rules.batch_axes + rules.model_axes)
        while parts and parts[-1] is None:
            parts.pop()
        return P(*parts)
    elif seq_dim < len(shape) and shape[seq_dim] % bsz == 0:
        parts[seq_dim] = bax
    if parts[seq_dim] is None and seq_dim < len(shape) \
            and shape[seq_dim] % msz == 0:
        parts[seq_dim] = max_
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)
