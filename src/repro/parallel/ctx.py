"""Activation-sharding context.

Model code is mesh-agnostic; the launcher installs the mesh axis names (and
sizes, for divisibility checks) here, and layers pin hot activations with
`shard_batch(x)` / `shard_spec(x, ...)`.  When unset (unit tests,
single-device runs) everything no-ops.

Why explicit constraints: GSPMD's propagation handles matmuls well but is
conservative around scatter/gather — the MoE dispatch buffer was replicated
(343 GiB/dev temp, 576 GiB/dev collectives) until pinned to the batch axes
(§Perf#3b).
"""

from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: tuple | None = None
_MODEL_AXES: tuple | None = None
_SIZES: dict[str, int] = {}


def set_axes(batch_axes: tuple | None, model_axes: tuple | None = None,
             sizes: dict[str, int] | None = None) -> None:
    global _BATCH_AXES, _MODEL_AXES, _SIZES
    _BATCH_AXES = tuple(batch_axes) if batch_axes else None
    _MODEL_AXES = tuple(model_axes) if model_axes else None
    _SIZES = dict(sizes or {})


def set_from_mesh(mesh, rules) -> None:
    set_axes(rules.batch_axes, rules.model_axes,
             {a: mesh.shape[a] for a in mesh.axis_names})


def clear() -> None:
    set_axes(None, None, None)


def batch_axes() -> tuple | None:
    return _BATCH_AXES


def _size(axes: tuple) -> int:
    return math.prod(_SIZES.get(a, 1) for a in axes)


def _norm(ax: tuple):
    return ax if len(ax) > 1 else ax[0]


def shard_batch(x: jax.Array, batch_dim: int = 0) -> jax.Array:
    """Pin dim `batch_dim` to the batch axes, rest replicated."""
    if _BATCH_AXES is None or x.shape[batch_dim] % _size(_BATCH_AXES):
        return x
    parts: list = [None] * x.ndim
    parts[batch_dim] = _norm(_BATCH_AXES)
    return jax.lax.with_sharding_constraint(x, P(*parts))


def shard_spec(x: jax.Array, *dims: str | None) -> jax.Array:
    """Pin dims by role: "batch" | "model" | None per dimension."""
    if _BATCH_AXES is None:
        return x
    parts: list = []
    for dim, role in zip(x.shape, dims):
        if role == "batch" and dim % _size(_BATCH_AXES) == 0:
            parts.append(_norm(_BATCH_AXES))
        elif role == "model" and _MODEL_AXES \
                and dim % _size(_MODEL_AXES) == 0:
            parts.append(_norm(_MODEL_AXES))
        else:
            parts.append(None)
    return jax.lax.with_sharding_constraint(x, P(*parts))
