"""Direct S3 access baseline (§6.3 "s3"): copy every object to node-local
disk via the S3 API before use — no cache reuse, duplicated bytes per node,
and an extra disk write+read on the critical path (the paper's CPU-cache
eviction point maps to the extra staging copy here)."""

from __future__ import annotations

from ..core.cos import CosStore
from ..core.simclock import HardwareModel, Resource, SimClock


class S3Direct:
    def __init__(self, cos: CosStore, bucket: str, clock: SimClock,
                 hw: HardwareModel | None = None, node: str = "s3",
                 parallel: int = 20, chunk_size: int = 16 * 1024 * 1024
                 ) -> None:
        self.cos = cos
        self.bucket = bucket
        self.clock = clock
        self.hw = hw or HardwareModel()
        self.disk = self.hw.make_disk(f"{node}-s3direct")
        self.parallel = parallel
        self.chunk_size = chunk_size
        self.staged: dict[str, bytes] = {}   # local disk copies
        self.stats: dict[str, int] = {}

    def _bump(self, k: str, n: int = 1) -> None:
        self.stats[k] = self.stats.get(k, 0) + n

    def download(self, key: str) -> bytes:
        """aws s3 cp s3://bucket/key /local — parallel ranged GETs, then a
        full local disk write (the staging copy)."""
        key = key.strip("/")
        size, t = self.cos.head_object(self.bucket, key, start=self.clock.now)
        lane = Resource("s3cp", float("inf"), 0.0, self.parallel)
        ends, parts = [], []
        for o in range(0, size, self.chunk_size):
            n = min(self.chunk_size, size - o)
            begin = lane.acquire(t, 0)
            data, te = self.cos.get_object(self.bucket, key, rng=(o, n),
                                           start=begin)
            parts.append(data)
            ends.append(te)
        t = max(ends) if ends else t
        blob = b"".join(parts)
        t = self.disk.acquire(t, len(blob))       # write staging copy
        self.clock.advance_to(t)
        self.staged[key] = blob
        self._bump("downloads")
        self._bump("downloaded_bytes", len(blob))
        return blob

    def read_local(self, key: str) -> bytes:
        """Application then reads the staged copy back from local disk."""
        blob = self.staged[key.strip("/")]
        t = self.disk.acquire(self.clock.now, len(blob))
        self.clock.advance_to(t)
        return blob

    def upload(self, key: str, data: bytes) -> None:
        key = key.strip("/")
        t = self.disk.acquire(self.clock.now, len(data))  # staging write
        lane = Resource("s3cp-up", float("inf"), 0.0, self.parallel)
        if len(data) <= self.chunk_size:
            t = self.cos.put_object(self.bucket, key, data, start=t)
        else:
            uid, t = self.cos.mpu_begin(self.bucket, key, start=t)
            ends = []
            for part, o in enumerate(range(0, len(data), self.chunk_size),
                                     start=1):
                begin = lane.acquire(t, 0)
                ends.append(self.cos.mpu_add(
                    uid, part, data[o:o + self.chunk_size], start=begin))
            t = self.cos.mpu_commit(uid, start=max(ends))
        self.clock.advance_to(t)
        self._bump("uploads")
