"""The paper's comparison points: S3FS-like wrapper FS and direct S3 copies."""

from .s3fs import S3FSConfig, S3FSLike
from .s3direct import S3Direct

__all__ = ["S3Direct", "S3FSConfig", "S3FSLike"]
