"""S3FS-like baseline: a *node-local* wrapper FS over COS (§2.1, §6).

Behavioural contract copied from s3fs-fuse as the paper configures it:

* per-node page cache (Linux page cache) — nothing is shared between nodes
  ("it cannot share downloaded files among nodes", §6.3);
* chunked parallel GETs with prefetch (the paper uses 52 MB chunks and
  20-way parallel multipart transfers, and 16 MB in §6.3);
* write-through on close: `close()` uploads the whole file synchronously via
  multipart upload ("S3FS synchronously uploaded files at every close",
  §6.4) — there is no dirty state, no crash recovery, no sharding;
* close-to-open consistency only.

Timing is charged against the same simulated COS endpoint and node NIC
resources the objcache cluster uses, so the comparison benchmarks (Figs.
9–12) are apples-to-apples.
"""

from __future__ import annotations

import itertools
from collections import OrderedDict
from dataclasses import dataclass, field

from ..core.cos import CosStore
from ..core.simclock import HardwareModel, Resource, SimClock
from ..core.types import Errno, FSError


@dataclass
class S3FSConfig:
    chunk_size: int = 52 * 1024 * 1024      # paper's FIO config
    parallel: int = 20                       # multipart parallelism
    prefetch_bytes: int = 1 << 30            # 1 GB prefetch window
    page_cache_bytes: int = 4 << 30
    use_page_cache: bool = True


@dataclass
class _OpenFile:
    path: str
    writable: bool
    data: bytearray = field(default_factory=bytearray)
    dirty: bool = False
    size: int = 0


class S3FSLike:
    """One instance per node (no cross-node state, exactly like s3fs)."""

    def __init__(self, cos: CosStore, bucket: str, clock: SimClock,
                 hw: HardwareModel | None = None,
                 cfg: S3FSConfig | None = None, node: str = "s3fs") -> None:
        self.cos = cos
        self.bucket = bucket
        self.clock = clock
        self.hw = hw or HardwareModel()
        self.cfg = cfg or S3FSConfig()
        self.nic = self.hw.make_nic(f"{node}-s3fs")
        # page cache: key -> (chunk_idx -> (bytes, ready_t))
        self._pages: OrderedDict[tuple[str, int], tuple[bytes, float]] = \
            OrderedDict()
        self._pages_bytes = 0
        self._fh = itertools.count(3)
        self._open: dict[int, _OpenFile] = {}
        self.stats: dict[str, int] = {}

    def _bump(self, k: str, n: int = 1) -> None:
        self.stats[k] = self.stats.get(k, 0) + n

    # ---- page cache -----------------------------------------------------------
    def _cache_put(self, key: str, idx: int, data: bytes, t: float) -> None:
        if not self.cfg.use_page_cache:
            return
        k = (key, idx)
        old = self._pages.pop(k, None)
        if old:
            self._pages_bytes -= len(old[0])
        self._pages[k] = (data, t)
        self._pages_bytes += len(data)
        while self._pages_bytes > self.cfg.page_cache_bytes and self._pages:
            _, (d, _) = self._pages.popitem(last=False)
            self._pages_bytes -= len(d)

    def _cache_get(self, key: str, idx: int) -> tuple[bytes, float] | None:
        ent = self._pages.get((key, idx))
        if ent is not None:
            self._pages.move_to_end((key, idx))
            self._bump("page_hits")
        return ent

    def invalidate(self, key: str) -> None:
        for k in [k for k in self._pages if k[0] == key]:
            d, _ = self._pages.pop(k)
            self._pages_bytes -= len(d)

    # ---- namespace -------------------------------------------------------------
    def listdir(self, prefix: str) -> list[str]:
        prefix = prefix.strip("/")
        pfx = prefix + "/" if prefix else ""
        objs, prefixes, t = self.cos.list_prefix(self.bucket, pfx,
                                                 start=self.clock.now)
        self.clock.advance_to(t)
        names = [k[len(pfx):] for k, _ in objs if k != pfx]
        names += [p[len(pfx):].rstrip("/") for p in prefixes]
        return sorted(n for n in names if n)

    def stat(self, path: str) -> dict:
        key = path.strip("/")
        try:
            size, t = self.cos.head_object(self.bucket, key,
                                           start=self.clock.now)
        except Exception:
            raise FSError(Errno.ENOENT, path) from None
        self.clock.advance_to(t)
        return {"size": size}

    def exists(self, path: str) -> bool:
        return self.cos.exists(self.bucket, path.strip("/"))

    def unlink(self, path: str) -> None:
        t = self.cos.delete_object(self.bucket, path.strip("/"),
                                   start=self.clock.now)
        self.clock.advance_to(t)
        self.invalidate(path.strip("/"))

    # ---- data ------------------------------------------------------------------
    def open(self, path: str, mode: str = "r") -> int:
        key = path.strip("/")
        f = _OpenFile(path=key, writable=any(m in mode for m in "wa+"))
        if "w" not in mode:
            try:
                size, t = self.cos.head_object(self.bucket, key,
                                               start=self.clock.now)
                self.clock.advance_to(t)
                f.size = size
            except Exception:
                if not f.writable:
                    raise FSError(Errno.ENOENT, path) from None
        fh = next(self._fh)
        self._open[fh] = f
        return fh

    def read(self, fh: int, off: int, length: int) -> bytes:
        f = self._open[fh]
        length = max(0, min(length, f.size - off))
        if length == 0:
            return b""
        cs = self.cfg.chunk_size
        first, last = off // cs, (off + length - 1) // cs
        # prefetch window (sequential assumption, like s3fs readahead)
        pre_last = min((off + self.cfg.prefetch_bytes - 1) // cs,
                       (f.size - 1) // cs)
        t0 = self.clock.now
        ready: dict[int, float] = {}
        chunks: dict[int, bytes] = {}
        lane = Resource("s3fs-par", float("inf"), 0.0, self.cfg.parallel)
        for idx in range(first, pre_last + 1):
            ent = self._cache_get(f.path, idx)
            if ent is not None:
                chunks[idx], ready[idx] = ent
                continue
            o = idx * cs
            n = min(cs, f.size - o)
            begin = lane.acquire(t0, 0)
            data, te = self.cos.get_object(self.bucket, f.path, rng=(o, n),
                                           start=begin)
            self._bump("cos_get")
            chunks[idx] = data
            ready[idx] = te
            self._cache_put(f.path, idx, data, te)
        need_end = max(ready[i] for i in range(first, last + 1))
        self.clock.advance_to(need_end)
        out = bytearray()
        for idx in range(first, last + 1):
            data = chunks[idx]
            s = max(off, idx * cs) - idx * cs
            e = min(off + length, (idx + 1) * cs) - idx * cs
            out += data[s:e]
        self._bump("read_bytes", len(out))
        return bytes(out)

    def write(self, fh: int, off: int, data: bytes) -> int:
        """Buffered locally; upload happens at close/fsync (write-through on
        close).  s3fs materializes the whole object locally to modify it."""
        f = self._open[fh]
        if not f.writable:
            raise FSError(Errno.EINVAL, "read-only handle")
        if not f.data and f.size and off != 0:
            # partial update forces a full download first (no partial PUT
            # on S3 — the paper's LPCC critique, §1)
            full = self.read(fh, 0, f.size)
            f.data = bytearray(full)
        if len(f.data) < off + len(data):
            f.data.extend(b"\0" * (off + len(data) - len(f.data)))
        f.data[off:off + len(data)] = data
        f.size = max(f.size, off + len(data))
        f.dirty = True
        self._bump("write_bytes", len(data))
        return len(data)

    def _upload(self, f: _OpenFile) -> None:
        cs = self.cfg.chunk_size
        data = bytes(f.data)
        t0 = self.clock.now
        if len(data) <= cs:
            t = self.cos.put_object(self.bucket, f.path, data, start=t0)
            self.clock.advance_to(t)
        else:
            uid, t = self.cos.mpu_begin(self.bucket, f.path, start=t0)
            lane = Resource("s3fs-up", float("inf"), 0.0, self.cfg.parallel)
            ends = []
            for part, o in enumerate(range(0, len(data), cs), start=1):
                begin = lane.acquire(t, 0)
                ends.append(self.cos.mpu_add(uid, part, data[o:o + cs],
                                             start=begin))
            t = self.cos.mpu_commit(uid, start=max(ends))
            self.clock.advance_to(t)
        self._bump("uploads")
        self.invalidate(f.path)
        f.dirty = False

    def fsync(self, fh: int) -> None:
        f = self._open[fh]
        if f.dirty:
            self._upload(f)

    def close(self, fh: int) -> None:
        f = self._open.pop(fh, None)
        if f is not None and f.dirty:
            self._upload(f)  # synchronous upload at every close (§6.4)

    # ---- convenience ------------------------------------------------------------
    def write_file(self, path: str, data: bytes) -> None:
        fh = self.open(path, "w")
        self.write(fh, 0, data)
        self.close(fh)

    def read_file(self, path: str) -> bytes:
        fh = self.open(path, "r")
        try:
            f = self._open[fh]
            return self.read(fh, 0, f.size)
        finally:
            self.close(fh)
