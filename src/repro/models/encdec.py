"""Encoder-decoder backbone (whisper-tiny family).

The conv audio frontend is a STUB per the brief: `input_specs()` provides
precomputed frame embeddings (B, enc_seq, d_model) — the output of whisper's
two conv layers.  The transformer backbone is real: a bidirectional encoder
with learned positions, and a causal decoder with learned positions and
cross-attention.  Learned positional tables are sized per shape
(max(448, seq)) as recorded in DESIGN.md §Arch-applicability.

Approximations vs the HF checkpoint (documented): RMSNorm instead of
LayerNorm, SwiGLU-style MLP replaced by a 2-matrix GELU MLP (matching
whisper's), RoPE not used (learned positions, as in whisper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ArchConfig
from .layers import (attention_init, attention_out, attention_qkv, embed,
                     embedding_init, rmsnorm, rmsnorm_init, rmsnorm_spec,
                     _dtype, _init_dense)


def _gelu_mlp_init(key, cfg: ArchConfig) -> tuple[dict, dict]:
    d, ff = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"w_in": _init_dense(k1, d, ff, dt),
         "w_out": _init_dense(k2, ff, d, dt,
                              scale=ff ** -0.5 / (2 * cfg.n_layers) ** 0.5)}
    return p, {"w_in": ("embed", "ff"), "w_out": ("ff", "embed")}


def _gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu((x @ p["w_in"]).astype(jnp.float32))
    return h.astype(x.dtype) @ p["w_out"]


def _attn_nopos(p: dict, x: jax.Array, cfg: ArchConfig, *, causal: bool,
                kv: jax.Array | None = None) -> jax.Array:
    """Attention without RoPE (learned positions added at embedding time).
    kv != None switches to cross-attention against encoder states."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    src = kv if kv is not None else x
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = (src @ p["wk"]).reshape(b, src.shape[1], cfg.n_kv_heads,
                                hd).transpose(0, 2, 1, 3)
    v = (src @ p["wv"]).reshape(b, src.shape[1], cfg.n_kv_heads,
                                hd).transpose(0, 2, 1, 3)
    o = kops.flash_attention(q, k, v, causal=causal, impl=cfg.attn_impl)
    return attention_out(p, o)


def _dec_layer_init(key, cfg: ArchConfig) -> tuple[dict, dict]:
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p, s = {}, {}
    p["self_norm"] = rmsnorm_init(cfg.d_model, dt)
    s["self_norm"] = rmsnorm_spec()
    p["self_attn"], s["self_attn"] = attention_init(ks[0], cfg)
    p["cross_norm"] = rmsnorm_init(cfg.d_model, dt)
    s["cross_norm"] = rmsnorm_spec()
    p["cross_attn"], s["cross_attn"] = attention_init(ks[1], cfg)
    p["ffn_norm"] = rmsnorm_init(cfg.d_model, dt)
    s["ffn_norm"] = rmsnorm_spec()
    p["mlp"], s["mlp"] = _gelu_mlp_init(ks[2], cfg)
    return p, s


def init_params(key, cfg: ArchConfig, max_seq: int) -> tuple[dict, dict]:
    dt = _dtype(cfg)
    n_pos = max(cfg.max_decoder_positions or 448, max_seq)
    ks = jax.random.split(key, cfg.enc_layers + cfg.n_layers + 4)
    enc_layers, enc_spec0 = [], None
    for i in range(cfg.enc_layers):
        ksl = jax.random.split(ks[i], 2)
        p = {"attn_norm": rmsnorm_init(cfg.d_model, dt),
             "ffn_norm": rmsnorm_init(cfg.d_model, dt)}
        s = {"attn_norm": rmsnorm_spec(), "ffn_norm": rmsnorm_spec()}
        p["attn"], s["attn"] = attention_init(ksl[0], cfg)
        p["mlp"], s["mlp"] = _gelu_mlp_init(ksl[1], cfg)
        enc_layers.append(p)
        enc_spec0 = enc_spec0 or s
    dec_layers, dec_spec0 = [], None
    for i in range(cfg.n_layers):
        p, s = _dec_layer_init(ks[cfg.enc_layers + i], cfg)
        dec_layers.append(p)
        dec_spec0 = dec_spec0 or s

    def stack(trees):
        return jax.tree.map(lambda *xs: jnp.stack(xs), *trees) \
            if len(trees) > 1 else jax.tree.map(lambda x: x[None], trees[0])

    def stack_spec(s):
        return jax.tree.map(lambda sp: ("layers",) + tuple(sp), s,
                            is_leaf=lambda x: isinstance(x, tuple))

    p = {
        "enc_layers": stack(enc_layers),
        "dec_layers": stack(dec_layers),
        "enc_pos": (jax.random.normal(ks[-1], (cfg.enc_seq, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dt),
        "dec_pos": (jax.random.normal(ks[-2], (n_pos, cfg.d_model),
                                      jnp.float32) * 0.02).astype(dt),
        "enc_norm": rmsnorm_init(cfg.d_model, dt),
        "dec_norm": rmsnorm_init(cfg.d_model, dt),
    }
    s = {
        "enc_layers": stack_spec(enc_spec0),
        "dec_layers": stack_spec(dec_spec0),
        "enc_pos": (None, "embed"),
        "dec_pos": (None, "embed"),
        "enc_norm": rmsnorm_spec(),
        "dec_norm": rmsnorm_spec(),
    }
    p["embed"], s["embed"] = embedding_init(ks[-3], cfg)  # tied head (whisper)
    return p, s


# =========================================================================
# forward
# =========================================================================
def encode(params: dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_seq, d_model) stub conv output."""
    x = frames.astype(_dtype(cfg)) + params["enc_pos"][None]

    def body(x, lp):
        h = rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
        x = x + _attn_nopos(lp["attn"], h, cfg, causal=False)
        h = rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        return x + _gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(params: dict, cfg: ArchConfig, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    s = tokens.shape[1]
    x = embed(params["embed"], tokens) + params["dec_pos"][None, :s]

    def body(x, lp):
        h = rmsnorm(lp["self_norm"], x, cfg.norm_eps)
        x = x + _attn_nopos(lp["self_attn"], h, cfg, causal=True)
        h = rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
        x = x + _attn_nopos(lp["cross_attn"], h, cfg, causal=False,
                            kv=enc_out)
        h = rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        return x + _gelu_mlp(lp["mlp"], h), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    return rmsnorm(params["dec_norm"], x, cfg.norm_eps)


def _ce_terms(table: jax.Array, hidden: jax.Array, labels: jax.Array
              ) -> tuple[jax.Array, jax.Array]:
    """Masked cross-entropy pieces for one sequence chunk (f32 logits live
    only within the chunk)."""
    logits = jnp.einsum("bsd,vd->bsv", hidden.astype(jnp.float32),
                        table.astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum((logz - gold) * mask), mask.sum()


def loss_fn(params: dict, cfg: ArchConfig, batch: dict,
            loss_chunk: int = 512) -> jax.Array:
    enc_out = encode(params, cfg, batch["frontend"])
    hidden = decode_train(params, cfg, batch["tokens"], enc_out)
    table = params["embed"]["table"]
    labels = batch["labels"]
    s = hidden.shape[1]
    # cross entropy over SEQUENCE CHUNKS with rematerialized bodies, as in
    # lm.loss_fn (§Perf#6): (B, S, V) f32 logits never exist at once
    if s % loss_chunk or s <= loss_chunk:
        nll, n = _ce_terms(table, hidden, labels)
    else:
        nc = s // loss_chunk
        hc = hidden.reshape(hidden.shape[0], nc, loss_chunk, -1)
        lc = labels.reshape(labels.shape[0], nc, loss_chunk)

        def chunk_body(carry, inp):
            h, l = inp
            t_nll, t_n = _ce_terms(table, h, l)
            return (carry[0] + t_nll, carry[1] + t_n), None

        (nll, n), _ = jax.lax.scan(
            jax.checkpoint(chunk_body),
            (jnp.zeros((), jnp.float32),) * 2,
            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    return nll / jnp.maximum(n, 1.0)


def prefill_fn(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    enc_out = encode(params, cfg, batch["frontend"])
    hidden = decode_train(params, cfg, batch["tokens"], enc_out)
    return jnp.einsum("bsd,vd->bsv", hidden[:, -1:].astype(jnp.float32),
                      params["embed"]["table"].astype(jnp.float32))


# =========================================================================
# serving
# =========================================================================
def cache_spec(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "k": jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, max_len, hd),
                                  jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((L, batch, cfg.n_kv_heads, max_len, hd),
                                  jnp.bfloat16),
        # cross-attention K/V precomputed from the encoder at prefill
        "cross_k": jax.ShapeDtypeStruct(
            (L, batch, cfg.n_kv_heads, cfg.enc_seq, hd), jnp.bfloat16),
        "cross_v": jax.ShapeDtypeStruct(
            (L, batch, cfg.n_kv_heads, cfg.enc_seq, hd), jnp.bfloat16),
    }


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_spec(cfg, batch, max_len))


def decode_fn(params: dict, cfg: ArchConfig, token: jax.Array, cache: dict,
              cache_len: jax.Array) -> tuple[jax.Array, dict]:
    b = token.shape[0]
    hd = cfg.resolved_head_dim
    pos_emb = jax.lax.dynamic_slice_in_dim(params["dec_pos"], cache_len, 1,
                                           axis=0)
    x = embed(params["embed"], token) + pos_emb[None, 0:1]

    def body(x, inp):
        lp, ck, cv, xk, xv = inp
        h = rmsnorm(lp["self_norm"], x, cfg.norm_eps)
        q = (h @ lp["self_attn"]["wq"]).reshape(b, 1, cfg.n_heads, hd
                                                ).transpose(0, 2, 1, 3)
        k = (h @ lp["self_attn"]["wk"]).reshape(b, 1, cfg.n_kv_heads, hd
                                                ).transpose(0, 2, 1, 3)
        v = (h @ lp["self_attn"]["wv"]).reshape(b, 1, cfg.n_kv_heads, hd
                                                ).transpose(0, 2, 1, 3)
        nk = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_len, axis=2)
        nv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_len, axis=2)
        mask = jnp.arange(nk.shape[2])[None, None, None, :] <= cache_len
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       nk.astype(jnp.float32)) * hd ** -0.5
        p = jax.nn.softmax(jnp.where(mask, s, -1e30), axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p,
                       nv.astype(jnp.float32)).astype(x.dtype)
        x = x + attention_out(lp["self_attn"], o)
        # cross attention against the cached encoder K/V
        h = rmsnorm(lp["cross_norm"], x, cfg.norm_eps)
        q = (h @ lp["cross_attn"]["wq"]).reshape(b, 1, cfg.n_heads, hd
                                                 ).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       xk.astype(jnp.float32)) * hd ** -0.5
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", p,
                       xv.astype(jnp.float32)).astype(x.dtype)
        x = x + attention_out(lp["cross_attn"], o)
        h = rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
        x = x + _gelu_mlp(lp["mlp"], h)
        return x, (nk, nv)

    x, (nks, nvs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    hidden = rmsnorm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", hidden.astype(jnp.float32),
                        params["embed"]["table"].astype(jnp.float32))
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = nks, nvs
    return logits, new_cache
