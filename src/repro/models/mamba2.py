"""Mamba2 block (SSD — state-space duality), per arXiv:2405.21060.

Projection layout per block (d_in = expand * d_model, H = d_in / head_dim):

  in_proj: d -> [z (d_in), x (d_in), B (d_state), C (d_state), dt (H)]
  conv1d : short causal depthwise conv over the (x, B, C) channels
  SSD    : h_t = a_t h_{t-1} + b_t ⊗ x_t,  y_t = c_t · h_t,
           a_t = exp(-softplus(dt_t + dt_bias) * exp(A_log))
  skip   : y += D ⊙ x ;  gate: y ⊙ silu(z); RMSNorm; out_proj.

B/C use a single group shared across heads (broadcast before the kernel).
The chunked scan runs through repro.kernels.ssd_scan (Pallas on TPU, jnp
oracle elsewhere); decode keeps (conv_state, ssm_state) caches and runs the
O(1) recurrence step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ArchConfig
from .layers import _dtype, _init_dense, rmsnorm, rmsnorm_init, rmsnorm_spec


def _dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads, s.d_state, s.head_dim


def mamba2_init(key, cfg: ArchConfig) -> tuple[dict, dict]:
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, n, p_dim = _dims(cfg)
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    conv_ch = d_in + 2 * n
    p = {
        "in_proj": _init_dense(ks[0], d, 2 * d_in + 2 * n + nh, dt),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                   * (s.d_conv ** -0.5)).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 1e-2))
                           ).astype(jnp.float32),
        "norm": rmsnorm_init(d_in, dt),
        "out_proj": _init_dense(ks[2], d_in, d, dt,
                                scale=d_in ** -0.5
                                / (2 * cfg.n_layers) ** 0.5),
    }
    spec = {
        "in_proj": ("embed", "ff"),
        "conv_w": (None, "ff"),
        "conv_b": ("ff",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": rmsnorm_spec(),
        "out_proj": ("ff", "embed"),
    }
    return p, spec


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    d_in, nh, n, _ = _dims(cfg)
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [d_in + 2 * n], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def mamba2_apply(params: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full-sequence (train / prefill) path.  x: (B, S, D)."""
    s_cfg = cfg.ssm
    bsz, seq, _ = x.shape
    d_in, nh, n, p_dim = _dims(cfg)
    proj = x @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, b_in, c_in = jnp.split(xbc, [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"])                   # (B, S, H)
    loga = -jnp.exp(params["A_log"])[None, None, :] * dt        # (B, S, H)
    xh = xs.reshape(bsz, seq, nh, p_dim)
    # single B/C group broadcast to every head, scaled by dt (ZOH discretize)
    bh = b_in[:, :, None, :] * dt[..., None]
    bh = jnp.broadcast_to(bh, (bsz, seq, nh, n)).astype(x.dtype)
    ch = jnp.broadcast_to(c_in[:, :, None, :],
                          (bsz, seq, nh, n)).astype(x.dtype)
    pad = (-seq) % s_cfg.chunk
    if pad and cfg.attn_impl != "jnp":
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
        bh = jnp.pad(bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ch = jnp.pad(ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, _ = kops.ssd_scan(xh, loga, bh, ch, chunk=s_cfg.chunk,
                         impl=cfg.attn_impl)
    y = y[:, :seq]
    y = (y + params["D"][None, None, :, None] * xh[:, :seq]).astype(x.dtype)
    y = y.reshape(bsz, seq, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return y @ params["out_proj"]


def mamba2_decode(params: dict, x: jax.Array, cfg: ArchConfig,
                  conv_state: jax.Array, ssm_state: jax.Array
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x (B, 1, D); conv_state (B, K-1, C);
    ssm_state (B, H, N, P) float32.  O(1) per step."""
    bsz = x.shape[0]
    d_in, nh, n, p_dim = _dims(cfg)
    proj = x @ params["in_proj"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    new_conv = jnp.concatenate([conv_state[:, 1:], xbc.astype(
        conv_state.dtype)], axis=1) if params["conv_w"].shape[0] > 1 \
        else conv_state
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                       state=conv_state)
    xs, b_in, c_in = jnp.split(xbc[:, 0], [d_in, d_in + n], axis=-1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"])                   # (B, H)
    a = jnp.exp(-jnp.exp(params["A_log"])[None, :] * dt)        # (B, H)
    xh = xs.reshape(bsz, nh, p_dim).astype(jnp.float32)
    bh = (b_in[:, None, :] * dt[..., None]).astype(jnp.float32)  # (B, H, N)
    ch = jnp.broadcast_to(c_in[:, None, :], (bsz, nh, n)
                          ).astype(jnp.float32)
    h = a[..., None, None] * ssm_state + jnp.einsum("bhn,bhp->bhnp", bh, xh)
    y = jnp.einsum("bhn,bhnp->bhp", ch, h)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return y @ params["out_proj"], new_conv, h
