"""Architecture configuration schema.

One `ArchConfig` describes any of the 10 assigned architectures; the
families map to model builders:

* dense/moe/ssm/hybrid/vlm — decoder-only LM (`models.lm`), where `vlm`
  prepends stub patch embeddings;
* audio — encoder-decoder (`models.encdec`) with a stub conv frontend
  (precomputed frame embeddings).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0            # shared (always-on) experts, qwen2-moe
    every: int = 1               # MoE replaces the MLP every `every` layers
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3  # router z-loss (stability)
    aux_coef: float = 1e-2       # load-balancing auxiliary loss


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    qk_norm: bool = False        # qwen3
    qkv_bias: bool = False       # qwen2/2.5
    window: int | None = None    # sliding-window attention (danube)
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (jamba): attention layer when (i % attn_period) == attn_offset,
    # else mamba; 0 disables (pure attention)
    attn_period: int = 0
    attn_offset: int = 0
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500          # whisper-tiny frame positions (stubbed)
    max_decoder_positions: int = 0   # 0 = unlimited (RoPE); whisper uses 448
    # modality frontend stubs: number of prepended embedding tokens
    frontend: str | None = None  # None | "patch" | "audio"
    n_frontend_tokens: int = 0
    # execution knobs
    attn_impl: str = "jnp"       # jnp | pallas | pallas_interpret
    dtype: str = "bfloat16"
    remat: str = "full"          # none | full | dots
    scan_layers: bool = True

    def with_(self, **kw) -> "ArchConfig":
        return replace(self, **kw)

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def is_attn_layer(self, i: int) -> bool:
        if self.attn_period == 0:
            return self.family != "ssm"
        return (i % self.attn_period) == self.attn_offset

    def is_moe_layer(self, i: int) -> bool:
        return self.moe is not None and (i % self.moe.every
                                         == self.moe.every - 1)

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM, hybrid, or sliding-window attention."""
        return (self.family in ("ssm", "hybrid")
                or self.window is not None)

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        qkv = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.qkv_bias:
            qkv += hd * (self.n_heads + 2 * self.n_kv_heads)
        mlp_dense = 3 * d * ff
        total = 0
        for i in range(self.n_layers):
            attn = self.is_attn_layer(i)
            if attn:
                total += qkv + 2 * d  # mixer + 2 norms
            elif self.ssm is not None:
                s = self.ssm
                d_in = s.expand * d
                nh = d_in // s.head_dim
                total += d * (2 * d_in + 2 * s.d_state + nh) \
                    + d_in * d + s.d_conv * (d_in + 2 * s.d_state) \
                    + 2 * nh + 2 * d
            if self.family == "ssm":
                continue  # mamba2 has no separate MLP
            if self.is_moe_layer(i):
                m = self.moe
                total += d * m.n_experts \
                    + 3 * d * m.d_ff_expert * (m.n_experts + m.n_shared)
            else:
                total += mlp_dense
            total += d  # ffn norm
        total += v * d * (1 if self.tie_embeddings else 2) + d
        if self.enc_layers:
            total += self.enc_layers * (qkv + mlp_dense + 3 * d) \
                + self.enc_seq * d
            # decoder cross-attention
            total += self.n_layers * (qkv + d)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.is_moe_layer(i))
        inactive = (m.n_experts - m.top_k) * 3 * self.d_model \
            * m.d_ff_expert * n_moe_layers
        return full - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell: (sequence length, global batch, step kind)."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
