"""Model zoo: one facade over the decoder-only LM and the enc-dec backbone.

`build_model(cfg)` returns a `Model` with a uniform functional surface used
by train/serve/launch:

    params, spec = model.init(key, max_seq)
    loss         = model.loss(params, batch)
    logits       = model.prefill(params, batch)
    logits, c2   = model.decode(params, token, cache, cache_len)
    spec_tree    = model.cache_spec(batch_size, max_len)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax

from . import encdec, lm
from .config import ArchConfig, MoEConfig, SSMConfig, ShapeConfig, SHAPES


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., tuple[dict, dict]]
    loss: Callable[..., jax.Array]
    prefill: Callable[..., jax.Array]
    decode: Callable[..., tuple[jax.Array, dict]]
    cache_spec: Callable[..., dict]
    init_cache: Callable[..., dict]


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        return Model(
            cfg=cfg,
            init=lambda key, max_seq=4096: encdec.init_params(key, cfg,
                                                              max_seq),
            loss=lambda p, batch: encdec.loss_fn(p, cfg, batch),
            prefill=lambda p, batch: encdec.prefill_fn(p, cfg, batch),
            decode=lambda p, tok, cache, n: encdec.decode_fn(p, cfg, tok,
                                                             cache, n),
            cache_spec=lambda b, s: encdec.cache_spec(cfg, b, s),
            init_cache=lambda b, s: encdec.init_cache(cfg, b, s),
        )
    return Model(
        cfg=cfg,
        init=lambda key, max_seq=4096: lm.init_params(key, cfg),
        loss=lambda p, batch: lm.loss_fn(p, cfg, batch),
        prefill=lambda p, batch: lm.prefill_fn(p, cfg, batch),
        decode=lambda p, tok, cache, n: lm.decode_fn(p, cfg, tok, cache, n),
        cache_spec=lambda b, s: lm.cache_spec(cfg, b, s),
        init_cache=lambda b, s: lm.init_cache(cfg, b, s),
    )


__all__ = ["ArchConfig", "MoEConfig", "Model", "SHAPES", "SSMConfig",
           "ShapeConfig", "build_model"]
