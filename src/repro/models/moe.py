"""Mixture-of-Experts layer: shared + routed top-k, capacity dispatch.

TPU-adapted GShard/Switch-style dispatch using a sort-based permutation
(no (T, E, C) one-hot tensor):

  1. router -> top-k expert ids + combine weights per token;
  2. token copies sorted by expert id; position-within-expert computed from
     group starts; copies beyond the expert capacity C are dropped;
  3. scatter into an (E, C, D) buffer; batched expert SwiGLU via einsum
     (one (E, D, F) matmul — MXU-friendly);
  4. gather back and combine with gate weights.

Expert weights shard on the ff dimension (tensor-parallel within experts) by
default — every assigned MoE arch has d_ff_expert divisible by 16 — with an
"expert" partition alternative (EP over the model axis) selectable for the
perf study.  Aux losses: load-balance (Switch) + router z-loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel import ctx
from .config import ArchConfig, MoEConfig
from .layers import _dtype, _init_dense, mlp, mlp_init


def moe_init(key, cfg: ArchConfig) -> tuple[dict, dict]:
    m = cfg.moe
    assert m is not None
    d, fe = cfg.d_model, m.d_ff_expert
    dt = _dtype(cfg)
    ks = jax.random.split(key, 5)
    scale_down = fe ** -0.5 / (2 * cfg.n_layers) ** 0.5
    p = {
        "router": _init_dense(ks[0], d, m.n_experts, jnp.float32, scale=0.02),
        "w_gate": (jax.random.normal(ks[1], (m.n_experts, d, fe), jnp.float32)
                   * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(ks[2], (m.n_experts, d, fe), jnp.float32)
                 * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(ks[3], (m.n_experts, fe, d), jnp.float32)
                   * scale_down).astype(dt),
    }
    s = {
        "router": ("embed", None),
        "w_gate": ("expert", "embed", "expert_ff"),
        "w_up": ("expert", "embed", "expert_ff"),
        "w_down": ("expert", "expert_ff", "embed"),
    }
    if m.n_shared:
        shared_p, shared_s = mlp_init(ks[4], cfg, d_ff=fe * m.n_shared)
        p["shared"] = shared_p
        s["shared"] = shared_s
    return p, s


def moe_apply(params: dict, x: jax.Array, cfg: ArchConfig,
              dropless: bool = False) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar).

    Dispatch is GROUPED on the batch dimension (GShard's G axis): each
    sequence dispatches into its own (E, Cg, D) sub-buffer, so under
    batch-sharded data parallelism the scatter, expert matmul and combine
    all stay shard-local — the ungrouped version scatter-added into a
    REPLICATED (E, C, D) buffer, which XLA lowered as ~400 GB of per-layer
    all-reduce (§Perf#3).  Capacity is per-group: Cg = ceil(S·k/E · cf).

    dropless=True sizes Cg at the worst case (every token of the group to
    one expert) — used at decode time where groups are single tokens and a
    drop would change served logits."""
    m: MoEConfig = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k

    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32),
                        params["router"])                       # (G, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)             # (G, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses -------------------------------------------------------
    # load-balance: E * sum_e (fraction of tokens to e) * (mean prob of e)
    chosen = jax.nn.one_hot(expert_ids[..., 0], e, dtype=jnp.float32)
    load = chosen.mean((0, 1))
    importance = probs.mean((0, 1))
    aux = m.aux_coef * e * jnp.sum(load * importance)
    aux = aux + m.router_z_coef * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- grouped sort-based capacity dispatch ------------------------------
    cap = s if dropless else int(max(1, (s * k // e) * m.capacity_factor))
    flat_e = expert_ids.reshape(b, s * k)                       # (G, S*k)
    order = jnp.argsort(flat_e, axis=1)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    counts = jax.vmap(lambda fe: jnp.bincount(fe, length=e))(flat_e)
    starts = jnp.cumsum(counts, axis=1) - counts                # (G, E)
    pos_sorted = jnp.arange(s * k)[None, :] \
        - jnp.take_along_axis(starts, sorted_e, axis=1)
    pos = jnp.zeros((b, s * k), jnp.int32).at[
        jnp.arange(b)[:, None], order].set(pos_sorted.astype(jnp.int32))
    keep = pos < cap                                            # (G, S*k)
    dst = jnp.where(keep, flat_e * cap + pos, e * cap)          # drop slot

    token_idx = jnp.repeat(jnp.arange(s), k)[None, :]           # (1, S*k)
    token_idx = jnp.broadcast_to(token_idx, (b, s * k))
    gidx = jnp.arange(b)[:, None]
    xk = jnp.take_along_axis(x, token_idx[..., None], axis=1)   # (G, S*k, D)
    xk = ctx.shard_batch(xk)
    buf = jnp.zeros((b, e * cap + 1, d), x.dtype)
    buf = ctx.shard_batch(buf)
    buf = buf.at[gidx, dst].add(xk * keep[..., None].astype(x.dtype))
    buf = ctx.shard_batch(buf)
    buf = buf[:, :-1].reshape(b, e, cap, d)
    buf = ctx.shard_spec(buf, "batch", None, None, "model")

    # ---- batched expert SwiGLU (weights broadcast over groups) -------------
    # bf16 end-to-end with f32 only inside the nonlinearity: keeps the
    # backward ff-contraction all-reduces in bf16 (halves §Perf#3c volume)
    g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    g = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    y = jnp.einsum("gecf,efd->gecd", g * u,
                   params["w_down"])                            # (G,E,Cg,D)
    y = ctx.shard_batch(y)

    # ---- combine -----------------------------------------------------------
    y_flat = jnp.concatenate([y.reshape(b, e * cap, d),
                              jnp.zeros((b, 1, d), y.dtype)], axis=1)
    picked = ctx.shard_batch(
        jnp.take_along_axis(y_flat, dst[..., None], axis=1))
    w = (gate_vals.reshape(b, s * k) * keep).astype(x.dtype)
    out = ctx.shard_batch(jnp.zeros((b, s, d), x.dtype))
    out = out.at[gidx, token_idx].add(picked * w[..., None])

    if m.n_shared:
        out = out + mlp(params["shared"], x.reshape(b * s, d)
                        ).reshape(b, s, d)
    return out, aux
