"""Core layers: norms, RoPE, GQA attention (qk-norm / bias / SWA), SwiGLU.

Pure-JAX parameter-dict modules: each layer is (init(key, cfg) -> params,
apply(params, x, ...) -> y).  Logical sharding axes for every parameter are
produced alongside init as a matching pytree of tuples (see
repro.parallel.sharding for the logical->mesh resolution).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels import ops as kops
from .config import ArchConfig


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


# =========================================================================
# norms
# =========================================================================
def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_spec() -> dict:
    return {"scale": ("embed",)}


def rmsnorm(params: dict, x: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# =========================================================================
# rotary position embedding
# =========================================================================
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, H, S, D), positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
        ang = ang[None, None]                      # (1, 1, S, half)
    else:
        ang = positions.astype(jnp.float32)[..., None] * freqs
        ang = ang[:, None]                         # (B, 1, S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), \
        x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# =========================================================================
# GQA attention
# =========================================================================
def attention_init(key, cfg: ArchConfig) -> tuple[dict, dict]:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init_dense(ks[0], d, nh * hd, dt),
        "wk": _init_dense(ks[1], d, nkv * hd, dt),
        "wv": _init_dense(ks[2], d, nkv * hd, dt),
        "wo": _init_dense(ks[3], nh * hd, d, dt,
                          scale=(nh * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    s = {
        "wq": ("embed", "q_proj"),
        "wk": ("embed", "kv_proj"),
        "wv": ("embed", "kv_proj"),
        "wo": ("q_proj", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nh * hd,), dt)
        p["bk"] = jnp.zeros((nkv * hd,), dt)
        p["bv"] = jnp.zeros((nkv * hd,), dt)
        s["bq"], s["bk"], s["bv"] = ("q_proj",), ("kv_proj",), ("kv_proj",)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
        s["q_norm"], s["k_norm"] = (None,), (None,)
    return p, s


def _head_rms(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def attention_qkv(params: dict, x: jax.Array, cfg: ArchConfig,
                  positions: jax.Array) -> tuple[jax.Array, jax.Array,
                                                 jax.Array]:
    """x (B, S, D) -> q (B, H, S, hd), k/v (B, Hkv, S, hd), roped."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    q = (x @ params["wq"])
    k = (x @ params["wk"])
    v = (x @ params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = _head_rms(q, params["q_norm"], cfg.norm_eps)
        k = _head_rms(k, params["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_out(params: dict, o: jax.Array) -> jax.Array:
    """o (B, H, S, hd) -> (B, S, D)."""
    b, h, s, hd = o.shape
    return o.transpose(0, 2, 1, 3).reshape(b, s, h * hd) @ params["wo"]


def attention(params: dict, x: jax.Array, cfg: ArchConfig,
              positions: jax.Array, *, causal: bool = True) -> jax.Array:
    q, k, v = attention_qkv(params, x, cfg, positions)
    o = kops.flash_attention(q, k, v, causal=causal, window=cfg.window,
                             impl=cfg.attn_impl)
    return attention_out(params, o)


def attention_decode(params: dict, x: jax.Array, cfg: ArchConfig,
                     cache_k: jax.Array, cache_v: jax.Array,
                     cache_len: jax.Array) -> tuple[jax.Array, jax.Array,
                                                    jax.Array]:
    """One-token decode: x (B, 1, D); cache_k/v (B, Hkv, S, hd) ring-ish
    buffers filled up to cache_len.  Returns (out, new_k, new_v)."""
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q, k, v = attention_qkv(params, x, cfg, pos)
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                             cache_len, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                             cache_len, axis=2)
    skv = ck.shape[2]
    # grouped GQA: never materialize the repeated (or fp32) cache — the
    # einsum reads bf16 K/V once and accumulates in f32 (perf log §Perf#1:
    # the repeat+astype version all-gathered 2×36 GiB per decode step)
    g = cfg.n_heads // cfg.n_kv_heads
    b, _, sq, hd = q.shape
    qg = q.reshape(b, cfg.n_kv_heads, g * sq, hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", qg, ck,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    idx = jnp.arange(skv)
    mask = idx[None, None, None, :] <= cache_len
    if cfg.window is not None:
        mask &= idx[None, None, None, :] > cache_len - cfg.window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(ck.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, cfg.n_heads, sq, hd).astype(x.dtype)
    return attention_out(params, o), ck, cv


def attention_decode_ring(params: dict, x: jax.Array, cfg: ArchConfig,
                          cache_k: jax.Array, cache_v: jax.Array,
                          cache_len: jax.Array) -> tuple[jax.Array, jax.Array,
                                                         jax.Array]:
    """Sliding-window decode with a ring-buffer cache of width W=window:
    slot i holds absolute position  cache_len - ((cache_len - i) mod W),
    so the cache is O(W) regardless of sequence length (the sub-quadratic
    long-context path for SWA architectures)."""
    w = cache_k.shape[2]
    b = x.shape[0]
    pos = jnp.full((b, 1), cache_len, jnp.int32)
    q, k, v = attention_qkv(params, x, cfg, pos)
    slot = cache_len % w
    ck = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                             slot, axis=2)
    cv = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                             slot, axis=2)
    idx = jnp.arange(w)
    abs_pos = cache_len - jnp.mod(cache_len - idx, w)
    mask = abs_pos >= 0
    g = cfg.n_heads // cfg.n_kv_heads
    bsz, _, sq, hd = q.shape
    qg = q.reshape(bsz, cfg.n_kv_heads, g * sq, hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", qg, ck,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    s = jnp.where(mask[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(ck.dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(bsz, cfg.n_heads, sq, hd).astype(x.dtype)
    return attention_out(params, o), ck, cv


# =========================================================================
# SwiGLU MLP
# =========================================================================
def mlp_init(key, cfg: ArchConfig, d_ff: int | None = None
             ) -> tuple[dict, dict]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3)
    p = {
        "w_gate": _init_dense(ks[0], d, ff, dt),
        "w_up": _init_dense(ks[1], d, ff, dt),
        "w_down": _init_dense(ks[2], ff, d, dt,
                              scale=ff ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }
    s = {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
         "w_down": ("ff", "embed")}
    return p, s


def mlp(params: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu((x @ params["w_gate"]).astype(jnp.float32))
    u = (x @ params["w_up"]).astype(jnp.float32)
    return ((g * u).astype(x.dtype)) @ params["w_down"]


# =========================================================================
# embedding / head
# =========================================================================
def embedding_init(key, cfg: ArchConfig) -> tuple[dict, dict]:
    dt = _dtype(cfg)
    p = {"table": (jax.random.normal(key, (cfg.vocab, cfg.d_model),
                                     jnp.float32) * 0.02).astype(dt)}
    return p, {"table": ("vocab", "embed")}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """x (B, S, D) -> logits (B, S, V) in float32."""
    return jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))
