"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are organized into repeating *periods* (dense: period 1; jamba:
period 8 with attention at slot 4 and MoE at odd slots), and the model scans
over stacked period parameters with jax.checkpoint applied per the remat
policy — HLO size stays O(period), activation memory O(n_periods · resid).

Three entry points (all pure functions of (params, batch)):

* `loss_fn`     — next-token cross entropy (+ MoE aux, z-loss), for train;
* `prefill_fn`  — forward returning hidden states and decode caches;
* `decode_fn`   — one-token step updating caches (the `serve_step` the
                  decode-shape dry-runs lower).

VLM (internvl2): stub patch embeddings (B, n_patches, frontend_dim) are
projected and prepended; labels are masked over patch positions.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from ..parallel import ctx
from .config import ArchConfig
from .layers import (attention, attention_decode, attention_decode_ring,
                     attention_init, embed, embedding_init, mlp, mlp_init,
                     rmsnorm, rmsnorm_init, rmsnorm_spec, unembed, _dtype,
                     _init_dense)
from .mamba2 import mamba2_apply, mamba2_decode, mamba2_init, _dims
from .moe import moe_apply, moe_init

FRONTEND_DIM = {"patch": 1024, "audio": 384}


def frontend_dim(cfg: ArchConfig) -> int:
    """Stub embedding width: ViT hidden for patch frontends; d_model for the
    audio conv stub (whisper's conv output is already d_model)."""
    if cfg.frontend == "audio":
        return cfg.d_model
    return FRONTEND_DIM[cfg.frontend]


# =========================================================================
# structure
# =========================================================================
def period_length(cfg: ArchConfig) -> int:
    p = 1
    if cfg.attn_period:
        p = cfg.attn_period
    if cfg.moe is not None:
        p = int(math.lcm(p, cfg.moe.every))
    return p


def n_periods(cfg: ArchConfig) -> int:
    p = period_length(cfg)
    assert cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p


def slot_kind(cfg: ArchConfig, slot: int) -> tuple[str, str]:
    """(mixer, ffn) for layer-index `slot` within a period."""
    mixer = "attn" if cfg.is_attn_layer(slot) else "ssm"
    if cfg.family == "ssm":
        ffn = "none"                       # mamba2 backbone has no MLP
    elif cfg.is_moe_layer(slot):
        ffn = "moe"
    else:
        ffn = "mlp"
    return mixer, ffn


# =========================================================================
# init
# =========================================================================
def _slot_init(key, cfg: ArchConfig, slot: int) -> tuple[dict, dict]:
    mixer, ffn = slot_kind(cfg, slot)
    kb, km, kf = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p: dict = {"mixer_norm": rmsnorm_init(cfg.d_model, dt)}
    s: dict = {"mixer_norm": rmsnorm_spec()}
    if mixer == "attn":
        p["attn"], s["attn"] = attention_init(km, cfg)
    else:
        p["ssm"], s["ssm"] = mamba2_init(km, cfg)
    if ffn != "none":
        p["ffn_norm"] = rmsnorm_init(cfg.d_model, dt)
        s["ffn_norm"] = rmsnorm_spec()
        if ffn == "moe":
            p["moe"], s["moe"] = moe_init(kf, cfg)
        else:
            p["mlp"], s["mlp"] = mlp_init(kf, cfg)
    return p, s


def init_params(key, cfg: ArchConfig) -> tuple[dict, dict]:
    """Returns (params, logical sharding spec pytree of the same shape)."""
    nper = n_periods(cfg)
    plen = period_length(cfg)
    keys = jax.random.split(key, nper * plen + 3)
    period_trees = []
    spec_slots = {}
    for per in range(nper):
        slots = {}
        for slot in range(plen):
            sp, ss = _slot_init(keys[per * plen + slot], cfg, slot)
            slots[f"slot{slot}"] = sp
            if per == 0:
                spec_slots[f"slot{slot}"] = ss
        period_trees.append(slots)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *period_trees) \
        if nper > 1 else jax.tree.map(lambda x: x[None], period_trees[0])
    spec_stacked = jax.tree.map(
        lambda spec: ("layers",) + tuple(spec),
        spec_slots, is_leaf=lambda x: isinstance(x, tuple))

    p = {"periods": stacked,
         "final_norm": rmsnorm_init(cfg.d_model, _dtype(cfg))}
    s = {"periods": spec_stacked, "final_norm": rmsnorm_spec()}
    p["embed"], s["embed"] = embedding_init(keys[-1], cfg)
    if not cfg.tie_embeddings:
        p["head"], s["head"] = embedding_init(keys[-2], cfg)
    if cfg.frontend is not None:
        fd = FRONTEND_DIM[cfg.frontend]
        p["frontend_proj"] = _init_dense(keys[-3], fd, cfg.d_model,
                                         _dtype(cfg))
        s["frontend_proj"] = (None, "embed")
    return p, s


# =========================================================================
# forward
# =========================================================================
def _apply_slot(sp: dict, x: jax.Array, cfg: ArchConfig, slot: int,
                positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    mixer, ffn = slot_kind(cfg, slot)
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(sp["mixer_norm"], x, cfg.norm_eps)
    if mixer == "attn":
        x = x + attention(sp["attn"], h, cfg, positions).astype(x.dtype)
    else:
        x = x + mamba2_apply(sp["ssm"], h, cfg).astype(x.dtype)
    # pin the residual's batch sharding at every slot: in heterogeneous
    # periods (jamba) GSPMD otherwise replicates the stream mid-period and
    # the MoE scatters blow up to global-batch all-reduces (§Perf#9)
    x = ctx.shard_batch(x)
    if ffn != "none":
        h = rmsnorm(sp["ffn_norm"], x, cfg.norm_eps)
        if ffn == "moe":
            y, aux = moe_apply(sp["moe"], h, cfg)
            x = x + y.astype(x.dtype)
        else:
            x = x + mlp(sp["mlp"], h).astype(x.dtype)
        x = ctx.shard_batch(x)
    return x, aux


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def backbone(params: dict, x: jax.Array, cfg: ArchConfig,
             positions: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, S, D) -> (hidden (B, S, D), aux loss)."""
    plen = period_length(cfg)

    def period_body(carry, pp):
        x, aux = carry
        for slot in range(plen):
            x, a = _apply_slot(pp[f"slot{slot}"], x, cfg, slot, positions)
            aux = aux + a
        # sequence-parallel residual (Megatron-SP): the scan saves the
        # inter-layer residual stack for backward — sharding its sequence
        # dim over the model axis cuts that stack 16x (§Perf#5: 60 GiB/dev
        # -> <4 GiB/dev for qwen2.5-14b train_4k)
        x = ctx.shard_spec(x, "batch", "model", None)
        return (x, aux), None

    body = _remat(cfg, period_body)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params["periods"])
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(n_periods(cfg)):
            pp = jax.tree.map(lambda a: a[i], params["periods"])
            (x, aux), _ = body((x, aux), pp)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def embed_inputs(params: dict, cfg: ArchConfig, tokens: jax.Array,
                 frontend: jax.Array | None) -> jax.Array:
    x = embed(params["embed"], tokens)
    if cfg.frontend is not None and frontend is not None:
        fe = frontend.astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([fe, x], axis=1)
    return ctx.shard_batch(x)


def logits_fn(params: dict, cfg: ArchConfig, hidden: jax.Array) -> jax.Array:
    table = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(table, hidden)


def _ce_terms(table: jax.Array, hidden: jax.Array, labels: jax.Array
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Cross-entropy pieces for one sequence chunk (f32 logits live only
    within the chunk)."""
    logits = jnp.einsum("bsd,vd->bsv", hidden.astype(jnp.float32),
                        table.astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return (jnp.sum((logz - gold) * mask), jnp.sum((logz ** 2) * mask),
            mask.sum())


def loss_fn(params: dict, cfg: ArchConfig, batch: dict,
            loss_chunk: int = 512) -> jax.Array:
    """batch: tokens (B, S_text) int32, labels (B, S_text) int32 (-1 =
    ignore), optional frontend (B, F, fd).

    The cross entropy is computed over SEQUENCE CHUNKS with rematerialized
    bodies, so the (B, S, V) f32 logits never exist at once — only
    (B, chunk, V) does (§Perf#6)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    fe = batch.get("frontend")
    x = embed_inputs(params, cfg, tokens, fe)
    positions = jnp.arange(x.shape[1])
    hidden, aux = backbone(params, x, cfg, positions)
    if fe is not None:   # loss only over text positions
        hidden = hidden[:, fe.shape[1]:]
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["head"]["table"]
    s = hidden.shape[1]
    if s % loss_chunk or s <= loss_chunk:
        nll, z2, n = _ce_terms(table, hidden, labels)
    else:
        nc = s // loss_chunk
        hc = hidden.reshape(hidden.shape[0], nc, loss_chunk, -1)
        lc = labels.reshape(labels.shape[0], nc, loss_chunk)

        def chunk_body(carry, inp):
            h, l = inp
            t_nll, t_z2, t_n = _ce_terms(table, h, l)
            return (carry[0] + t_nll, carry[1] + t_z2, carry[2] + t_n), None

        (nll, z2, n), _ = jax.lax.scan(
            jax.checkpoint(chunk_body),
            (jnp.zeros((), jnp.float32),) * 3,
            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)))
    denom = jnp.maximum(n, 1.0)
    return nll / denom + 1e-4 * z2 / denom + aux


# =========================================================================
# serving: caches, prefill, decode
# =========================================================================
def cache_spec(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """ShapeDtypeStructs for the decode caches (also used by input_specs)."""
    plen = period_length(cfg)
    nper = n_periods(cfg)
    spec: dict = {}
    for slot in range(plen):
        mixer, _ = slot_kind(cfg, slot)
        if mixer == "attn":
            hd = cfg.resolved_head_dim
            kv_len = min(max_len, cfg.window) if cfg.window else max_len
            shp = (nper, batch, cfg.n_kv_heads, kv_len, hd)
            spec[f"slot{slot}"] = {
                "k": jax.ShapeDtypeStruct(shp, jnp.bfloat16),
                "v": jax.ShapeDtypeStruct(shp, jnp.bfloat16),
            }
        else:
            d_in, nh, n, p_dim = _dims(cfg)
            conv_ch = d_in + 2 * n
            spec[f"slot{slot}"] = {
                "conv": jax.ShapeDtypeStruct(
                    (nper, batch, cfg.ssm.d_conv - 1, conv_ch),
                    jnp.bfloat16),
                "ssm": jax.ShapeDtypeStruct((nper, batch, nh, n, p_dim),
                                            jnp.float32),
            }
    return spec


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                        cache_spec(cfg, batch, max_len))


def prefill_fn(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Forward over the prompt; returns last-position logits.  (Cache
    construction during prefill reuses the same backbone; the dry-run
    prefill cell lowers exactly this compute.)"""
    tokens = batch["tokens"]
    fe = batch.get("frontend")
    x = embed_inputs(params, cfg, tokens, fe)
    positions = jnp.arange(x.shape[1])
    hidden, _ = backbone(params, x, cfg, positions)
    return logits_fn(params, cfg, hidden[:, -1:])


def decode_fn(params: dict, cfg: ArchConfig, token: jax.Array, cache: dict,
              cache_len: jax.Array) -> tuple[jax.Array, dict]:
    """One decode step: token (B, 1) -> (logits (B, 1, V), new cache)."""
    x = embed(params["embed"], token)
    plen = period_length(cfg)

    def period_body(x, inp):
        pp, cc = inp
        new_cc = {}
        for slot in range(plen):
            sp = pp[f"slot{slot}"]
            cs = cc[f"slot{slot}"]
            mixer, ffn = slot_kind(cfg, slot)
            h = rmsnorm(sp["mixer_norm"], x, cfg.norm_eps)
            if mixer == "attn":
                # ring buffer iff the cache was allocated at window size
                use_ring = (cfg.window is not None
                            and cs["k"].shape[2] == cfg.window)
                dec = attention_decode_ring if use_ring else attention_decode
                o, nk, nv = dec(sp["attn"], h, cfg, cs["k"], cs["v"],
                                cache_len)
                x = x + o
                new_cc[f"slot{slot}"] = {"k": nk, "v": nv}
            else:
                o, nconv, nssm = mamba2_decode(sp["ssm"], h, cfg,
                                               cs["conv"], cs["ssm"])
                x = x + o
                new_cc[f"slot{slot}"] = {"conv": nconv, "ssm": nssm}
            if ffn != "none":
                h = rmsnorm(sp["ffn_norm"], x, cfg.norm_eps)
                if ffn == "moe":
                    y, _ = moe_apply(sp["moe"], h, cfg, dropless=True)
                    x = x + y.astype(x.dtype)
                else:
                    x = x + mlp(sp["mlp"], h).astype(x.dtype)
        return x, new_cc

    x, new_cache = jax.lax.scan(period_body, x, (params["periods"], cache))
    hidden = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return logits_fn(params, cfg, hidden), new_cache
