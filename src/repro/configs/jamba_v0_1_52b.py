"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Mamba+attention 1:7 interleave (attention at slot 4 of every
8-layer period), MoE 16 experts top-2 every other layer.
[arXiv:2403.19887; hf]"""

from ..models.config import ArchConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=65536, rope_theta=1e4,
    attn_period=8, attn_offset=4,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every=2),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, d_conv=4, chunk=128),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, attn_period=4, attn_offset=2,
                        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                                      every=2),
                        ssm=SSMConfig(d_state=16, head_dim=16, expand=2,
                                      d_conv=4, chunk=32))
