"""mamba2-370m [ssm] — 48L d_model=1024, attention-free, vocab=50280
(padded to 50304 = 393*128), ssm_state=128, SSD (state-space duality).
[arXiv:2405.21060; unverified]"""

from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50304, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=128),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=64,
                        ssm=SSMConfig(d_state=16, head_dim=16, expand=2,
                                      d_conv=4, chunk=32))
