"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936; qk_norm, GQA, head_dim=128, tied embeddings.
[hf:Qwen/Qwen3-0.6B family per brief; hf]"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, head_dim=16)
