"""whisper-tiny [audio] — 4L encoder + 4L decoder, d_model=384 6H (kv=6)
d_ff=1536 vocab=51865 (padded to 51968 = 406*128); enc-dec with a STUB conv
frontend — input_specs() provides precomputed frame embeddings (B, 1500,
384).  Decoder positions are learned; the table is sized per shape
(max(448, seq)).  [arXiv:2212.04356; unverified]"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51968, tie_embeddings=True,
    enc_layers=4, enc_seq=1500, max_decoder_positions=448,
    frontend="audio", n_frontend_tokens=1500,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                        n_kv_heads=4, d_ff=128, vocab=512, enc_seq=32,
                        n_frontend_tokens=32)
