"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 (padded to 92672 = 724*128 for 16-way TP; padding ids are never
targeted); InternViT patch frontend is a STUB — input_specs() provides
precomputed patch embeddings (B, 256, 1024).  [arXiv:2404.16821; hf]"""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=8192, vocab=92672, rope_theta=1e6,
    frontend="patch", n_frontend_tokens=256,
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=512, n_frontend_tokens=8)
