"""Architecture registry: --arch <id> resolution for the 10 assigned
architectures (exact configs from public literature; see each module's
docstring for the source tier)."""

from __future__ import annotations

import importlib

from ..models.config import ArchConfig, SHAPES, ShapeConfig

_MODULES = {
    "qwen3-0.6b": "qwen3_0_6b",
    "qwen2.5-14b": "qwen2_5_14b",
    "granite-8b": "granite_8b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "internvl2-2b": "internvl2_2b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-370m": "mamba2_370m",
}

ARCH_IDS = tuple(_MODULES)


def _mod(arch: str):
    key = arch.lower().replace("_", "-")
    if key not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    return importlib.import_module(f".{_MODULES[key]}", __package__)


def get_config(arch: str) -> ArchConfig:
    return _mod(arch).CONFIG


def get_reduced(arch: str) -> ArchConfig:
    return _mod(arch).reduced()


def cells(include_skipped: bool = False
          ) -> list[tuple[str, ShapeConfig, bool]]:
    """All 40 (arch, shape) cells with a runnable flag.  long_500k is
    skipped for pure full-attention archs (sub-quadratic requirement,
    DESIGN.md §Arch-applicability)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            runnable = True
            if shape.name == "long_500k" and not cfg.supports_long_context():
                runnable = False
            if runnable or include_skipped:
                out.append((arch, shape, runnable))
    return out


__all__ = ["ARCH_IDS", "SHAPES", "cells", "get_config", "get_reduced"]
