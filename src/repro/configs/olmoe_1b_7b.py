"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304; 64 routed experts top-8, qk-norm.  [arXiv:2409.02060; hf]"""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304, qk_norm=True, rope_theta=1e4,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024, every=1),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab=512,
                        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                                      every=1))
