"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936; MoE 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=5632, vocab=151936, qkv_bias=True, rope_theta=1e6,
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408, n_shared=4,
                  every=1),
)


def reduced() -> ArchConfig:
    return CONFIG.with_(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                        d_ff=128, vocab=512,
                        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32,
                                      n_shared=2, every=1))
