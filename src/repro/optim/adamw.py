"""AdamW with fp32 master weights, global-norm clipping, warmup+cosine LR.

Mixed-precision discipline (MaxText-style): model params live in bf16 for
compute; the optimizer keeps fp32 master weights plus (m, v).  Under ZeRO-1
(repro.parallel.zero1_shardings) master/m/v shard over the data axes, so XLA
reduce-scatters grads into optimizer shards and all-gathers the updated bf16
params — the classic distributed-optimizer communication pattern.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = (s - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> dict:
    f32 = lambda p: p.astype(jnp.float32)
    return {
        "master": jax.tree.map(f32, params),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(cfg: AdamWConfig, grads, opt_state: dict
                 ) -> tuple[dict, dict, dict]:
    """Returns (new_params_bf16-tree-matching-master-dtypes, new_opt_state,
    metrics).  New params are cast back to the original param dtypes by the
    caller (we return them in fp32 master precision here? no — we cast to
    the master's compute dtype recorded below)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    gf = jax.tree.map(lambda g: g * scale, gf)

    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         opt_state["m"], gf)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         opt_state["v"], gf)

    def upd(master, m, v):
        mhat = m / b1c
        vhat = v / b2c
        return master - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                              + cfg.weight_decay * master)

    new_master = jax.tree.map(upd, opt_state["master"], new_m, new_v)
    new_opt = {"master": new_master, "m": new_m, "v": new_v, "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}
    return new_master, new_opt, metrics
