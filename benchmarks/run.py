"""Benchmark harness: one benchmark per paper figure + the roofline table.

    PYTHONPATH=src python -m benchmarks.run [--skip-roofline]
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip reading the dry-run reports")
    args = ap.parse_args()

    from . import (consistency_models, elasticity, fio_seqread,
                   serving_startup, training_io)

    t0 = time.time()
    print("== Fig 9: cache tiering (FIO sequential read) ==")
    fio_seqread.run()
    print("== Fig 10: consistency x deployment models ==")
    consistency_models.run(nodes=(1, 2, 4, 8))
    print("== Fig 11: model-serving startup ==")
    serving_startup.run()
    print("== Fig 12: training workload I/O ==")
    training_io.run()
    print("== Figs 13/14: elasticity + migration ==")
    elasticity.run()
    if not args.skip_roofline:
        print("== Roofline (from dry-run artifacts) ==")
        from . import roofline
        rows = roofline.run(quiet=True)
        if rows:
            worst = min(rows, key=lambda r: r["roofline_fraction"])
            print(f"[roofline] {len(rows)} cells analysed; worst fraction "
                  f"{worst['roofline_fraction']:.3f} "
                  f"({worst['arch']} x {worst['shape']}); "
                  f"table at reports/roofline.md")
        else:
            print("[roofline] no dry-run reports found — run "
                  "`python -m repro.launch.dryrun --all` first")
    print(f"== all benchmarks done in {time.time() - t0:.1f}s ==")
    return 0


if __name__ == "__main__":
    sys.exit(main())
