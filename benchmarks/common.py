"""Shared benchmark scaffolding: scaled workload sizes + result IO.

Workloads are scaled down from the paper's (4 GB files, 36 nodes, 43 GB
models) to keep wall-time short; the virtual-time hardware model preserves
the *ratios* the paper reports, which is what §Paper-fidelity checks."""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import shutil
import sys
import tempfile
from dataclasses import dataclass

import numpy as np

from repro.core import (BucketMount, ClientConfig, Cluster, CosStore,
                        HardwareModel, NvmeStore, ObjcacheClient, ObjcacheFS,
                        ServerConfig, SimClock, TierPolicy, TieredStore)

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "bench")

CHUNK = 1 << 20          # 1 MiB chunks (paper: 16 MiB; scaled 1/16)
FILE_MB = 64             # Fig 9 file (paper: 4 GiB; scaled 1/64)


def blob(n: int, seed: int = 0) -> bytes:
    return bytes(np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8))


def make_cluster(workdir: str, n: int, chunk: int = CHUNK,
                 bucket: str = "bench", hw: HardwareModel | None = None,
                 cfg: ServerConfig | None = None,
                 backends: dict | None = None, backend: str = "cos",
                 clock: SimClock | None = None) -> Cluster:
    cl = Cluster(workdir, [BucketMount(bucket, bucket, backend=backend)],
                 hw=hw, cfg=cfg or ServerConfig(chunk_size=chunk),
                 clock=clock, backends=backends)
    cl.start(n)
    return cl


@contextlib.contextmanager
def bench_env(prefix: str, n: int, chunk: int = CHUNK, bucket: str = "bench",
              hw: HardwareModel | None = None,
              cfg: ServerConfig | None = None,
              backends: dict | None = None, backend: str = "cos",
              clock: SimClock | None = None):
    """Temp workdir + started cluster, torn down on exit — the setup every
    benchmark used to hand-roll (mkdtemp / close / rmtree).  Pass
    ``backends={"tiered": store}, backend="tiered"`` to mount the bench
    bucket on a pluggable backend (core/tiering.py) instead of default COS;
    share a pre-built ``clock`` so backend lanes and cluster time agree."""
    wd = tempfile.mkdtemp(prefix=prefix)
    cl = make_cluster(wd, n=n, chunk=chunk, bucket=bucket, hw=hw, cfg=cfg,
                      backends=backends, backend=backend, clock=clock)
    try:
        yield cl
    finally:
        cl.close()
        shutil.rmtree(wd, ignore_errors=True)


def make_fs(cl: Cluster, consistency: str = "weak",
            deployment: str = "detached", node: str | None = None,
            readahead: int = 8, client_id: int | None = None) -> ObjcacheFS:
    client = ObjcacheClient(
        cl.router, cl.clock, node or cl.node_list()[0],
        ClientConfig(consistency=consistency, deployment=deployment,
                     readahead_chunks=readahead),
        chunk_size=cl.cfg.chunk_size, client_id=client_id)
    return ObjcacheFS(client)


def save_report(name: str, payload: dict) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def mbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-9) / 1e6


def pctl(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs, dtype=float), q)) if xs else 0.0


def fastpath_off(cl: Cluster) -> None:
    """Disable the metadata fast paths (PR 7) on a running cluster: no lease
    grants and no same-destination RPC batching.  Both knobs are read at use
    time, so flipping them on the shared ServerConfig is enough."""
    cl.cfg.lease_ttl_s = 0.0
    cl.cfg.batch_rpcs = False


def fastpath_section(n_nodes: int = 4, n_dirs: int = 4,
                     files_per_dir: int = 8, rounds: int = 3,
                     migrate: bool = False) -> dict:
    """Before/after probe for the metadata fast paths (leases + batching):
    the same stat/listdir-heavy workload on a fresh cluster with the fast
    paths off vs on.  Reports total RPC envelopes, envelopes spent in the
    metadata loop, and metadata-op p50/p99 in virtual time; with
    `migrate=True` also the envelope cost of one node join (meta handoffs
    coalesce to O(destinations) envelopes when batching is on)."""
    out: dict = {}
    for mode in ("off", "on"):
        with bench_env(f"bench-fastpath-{mode}-", n=n_nodes) as cl:
            if mode == "off":
                fastpath_off(cl)
            fs = make_fs(cl)
            for d in range(n_dirs):
                fs.makedirs(f"/bench/d{d}")
            for d in range(n_dirs):
                for i in range(files_per_dir):
                    fs.write_file(f"/bench/d{d}/f{i}.bin",
                                  blob(4096, d * 64 + i))
            loop_t0, loop_env = cl.clock.now, cl.router.rpc_count
            lat: list[float] = []
            for _ in range(rounds):
                for d in range(n_dirs):
                    t0 = cl.clock.now
                    fs.listdir(f"/bench/d{d}")
                    lat.append(cl.clock.now - t0)
                    for i in range(files_per_dir):
                        t0 = cl.clock.now
                        fs.stat(f"/bench/d{d}/f{i}.bin")
                        lat.append(cl.clock.now - t0)
            cell = {
                "rpc_envelopes_total": cl.router.rpc_count,
                "rpc_envelopes_meta_loop": cl.router.rpc_count - loop_env,
                "meta_loop_s": round(cl.clock.now - loop_t0, 6),
                "meta_ops": len(lat),
                "meta_p50_ms": round(pctl(lat, 50) * 1e3, 6),
                "meta_p99_ms": round(pctl(lat, 99) * 1e3, 6),
                "batched_subcalls": cl.router.batched_subcalls,
                "lease_hits": sum(fs.client.stats.get(k, 0) for k in
                                  ("lease_attr_hits", "lease_lookup_hits",
                                   "lease_readdir_hits")),
            }
            if migrate:
                env0 = cl.router.rpc_count
                t0 = cl.clock.now
                cl.add_node()
                cell["join_envelopes"] = cl.router.rpc_count - env0
                cell["join_s"] = round(cl.clock.now - t0, 6)
            out[mode] = cell
    off, on = out["off"], out["on"]
    out["rpc_reduction_pct"] = round(100 * (1 - on["rpc_envelopes_total"] /
                                            max(off["rpc_envelopes_total"],
                                                1)), 1)
    out["meta_p99_reduction_pct"] = round(
        100 * (1 - on["meta_p99_ms"] / max(off["meta_p99_ms"], 1e-9)), 1)
    return out


def make_tier(clock: SimClock, hw: HardwareModel | None = None,
              nvme_mb: int = 64, promote_min_hits: int = 2,
              writeback: bool = True) -> TieredStore:
    """Standard two-tier store for the benchmarks: bounded local-NVMe cache
    over an unbounded durable S3-like base (see docs/STORAGE.md)."""
    hw = hw or HardwareModel()
    return TieredStore([NvmeStore(clock, capacity_bytes=nvme_mb << 20),
                        CosStore(clock, hw)], clock,
                       TierPolicy(promote_min_hits=promote_min_hits,
                                  writeback=writeback))


def tier_sweep_section(n_nodes: int = 4, n_files: int = 8,
                       file_kb: int = 2560, nvme_mb: int = 64) -> dict:
    """Cold/warm/hot read sweep over a tiered bucket mount.

    One TieredStore (NVMe cache over durable S3-like base) is shared by two
    consecutive cluster generations reading the same object set:

    * cold — first generation, nothing cached anywhere: every chunk is a
      ranged GET against the durable base, and repeated hits on each key
      trigger promotion into the NVMe tier.
    * warm — second generation (fresh cluster cache) over the same backend:
      chunk fills are served by the promoted NVMe copies.
    * hot  — re-read within a generation: chunks are cluster-cache resident,
      no backend traffic at all.

    Files are deliberately larger than the chunk size so a single cold file
    read produces enough per-key GETs to cross ``promote_min_hits``; the
    NVMe tier must hold the whole working set, because a sequential scan
    over a too-small LRU cache thrashes — every file then pays one base GET
    for its first chunk and, with readahead firing a file's chunk fills in
    parallel, that one GET dominates the file latency and erases the warm
    win (capacity-pressure behaviour is pinned by tests/test_tiering.py
    instead)."""
    clock = SimClock()
    tier = make_tier(clock, nvme_mb=nvme_mb)
    total = 0
    for i in range(n_files):
        data = blob(file_kb << 10, i)
        total += len(data)
        tier.base.put_object("bench", f"f{i}.bin", data)

    def generation(label: str) -> tuple[float, float]:
        with bench_env(f"bench-tier-{label}-", n=n_nodes,
                       backends={"tiered": tier}, backend="tiered",
                       clock=clock) as cl:
            fs = make_fs(cl)
            t0 = cl.clock.now
            for i in range(n_files):
                fs.read_file(f"/bench/f{i}.bin")
            first = cl.clock.now - t0
            t0 = cl.clock.now
            for i in range(n_files):
                fs.read_file(f"/bench/f{i}.bin")
            resident = cl.clock.now - t0
        return first, resident

    cold_s, hot_s = generation("cold")
    warm_s, _ = generation("warm")
    stats = tier.stats()
    return {
        "files": n_files, "file_kb": file_kb, "total_mb": round(total / 1e6, 1),
        "nvme_mb": nvme_mb, "nodes": n_nodes,
        "cold_s": round(cold_s, 6), "warm_s": round(warm_s, 6),
        "hot_s": round(hot_s, 6),
        "warm_speedup": round(cold_s / max(warm_s, 1e-9), 2),
        "hot_speedup": round(cold_s / max(hot_s, 1e-9), 2),
        "tier": stats,
    }


# -------------------------------------------------------------------------
# baseline regression gates (shared by the *_smoke benchmarks in check.sh)
# -------------------------------------------------------------------------
@dataclass(frozen=True)
class Gate:
    """One gated metric: the report value at (possibly dotted) key `metric`
    must not exceed `baseline * (1 + tolerance) + slack`.  `slack` is the
    absolute headroom for near-zero baselines (e.g. a 0.0 shed rate, where
    any multiplicative tolerance would forbid a single shed)."""

    metric: str
    tolerance: float = 0.20
    slack: float = 0.0


def dig(d: dict, dotted: str):
    for part in dotted.split("."):
        d = d[part]
    return d


def check_baseline(tag: str, rep: dict, baseline_file: str,
                   gates: list[Gate]) -> int:
    path = os.path.join(REPORT_DIR, baseline_file)
    if not os.path.exists(path):
        print(f"[{tag}] no baseline at {path}; run --update-baseline first",
              file=sys.stderr)
        return 1
    with open(path) as f:
        base = json.load(f)
    rc = 0
    for g in gates:
        cur, ref = dig(rep, g.metric), dig(base, g.metric)
        limit = ref * (1.0 + g.tolerance) + g.slack
        if cur > limit:
            print(f"[{tag}] REGRESSION: {g.metric} {cur} > {limit:.4f} "
                  f"(baseline {ref} +{g.tolerance:.0%}"
                  f"{f' +{g.slack}' if g.slack else ''})", file=sys.stderr)
            rc = 1
    if rc == 0:
        ok = ", ".join(f"{g.metric}={dig(rep, g.metric)}" for g in gates)
        print(f"[{tag}] OK: {ok} within tolerance of baseline")
    return rc


def gate_main(tag: str, run_fn, baseline_file: str, gates: list[Gate],
              baseline_keys: list[str]) -> int:
    """The --check / --update-baseline CLI shared by the smoke benchmarks:
    run the workload, then either gate the listed metrics against the
    checked-in baseline or record a new baseline from `baseline_keys`."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if gated metrics regress vs baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="record current metrics as the baseline")
    args = ap.parse_args()
    rep = run_fn()
    if args.update_baseline:
        os.makedirs(REPORT_DIR, exist_ok=True)
        payload: dict = {}
        for key in baseline_keys:
            cur: dict = payload
            parts = key.split(".")
            for part in parts[:-1]:
                cur = cur.setdefault(part, {})
            cur[parts[-1]] = dig(rep, key)
        with open(os.path.join(REPORT_DIR, baseline_file), "w") as f:
            json.dump(payload, f, indent=1)
        print(f"[{tag}] baseline updated: " + ", ".join(
            f"{g.metric}={dig(rep, g.metric)}" for g in gates))
        return 0
    if args.check:
        return check_baseline(tag, rep, baseline_file, gates)
    return 0


def rpc_summary(cl: Cluster, top: int = 8) -> dict:
    """Per-method RPC fabric stats from the typed dispatch table, for the
    benchmark reports: calls, megabytes on the wire, summed virtual-time
    latency — the `top` busiest methods by call count."""
    rows = sorted(cl.rpc_stats().items(), key=lambda kv: -kv[1]["calls"])
    return {m: {"calls": int(v["calls"]),
                "mbytes": round(v["bytes"] / 1e6, 3),
                "vtime_s": round(v["vtime"], 6),
                "timeouts": int(v["timeouts"]),
                "errors": int(v["errors"])}
            for m, v in rows[:top]}
