"""Shared benchmark scaffolding: scaled workload sizes + result IO.

Workloads are scaled down from the paper's (4 GB files, 36 nodes, 43 GB
models) to keep wall-time short; the virtual-time hardware model preserves
the *ratios* the paper reports, which is what §Paper-fidelity checks."""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core import (BucketMount, ClientConfig, Cluster, ObjcacheClient,
                        ObjcacheFS, ServerConfig)

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "bench")

CHUNK = 1 << 20          # 1 MiB chunks (paper: 16 MiB; scaled 1/16)
FILE_MB = 64             # Fig 9 file (paper: 4 GiB; scaled 1/64)


def blob(n: int, seed: int = 0) -> bytes:
    return bytes(np.random.default_rng(seed).integers(
        0, 256, size=n, dtype=np.uint8))


def make_cluster(workdir: str, n: int, chunk: int = CHUNK,
                 bucket: str = "bench") -> Cluster:
    cl = Cluster(workdir, [BucketMount(bucket, bucket)],
                 cfg=ServerConfig(chunk_size=chunk))
    cl.start(n)
    return cl


def make_fs(cl: Cluster, consistency: str = "weak",
            deployment: str = "detached", node: str | None = None,
            readahead: int = 8) -> ObjcacheFS:
    client = ObjcacheClient(
        cl.router, cl.clock, node or cl.node_list()[0],
        ClientConfig(consistency=consistency, deployment=deployment,
                     readahead_chunks=readahead),
        chunk_size=cl.cfg.chunk_size)
    return ObjcacheFS(client)


def save_report(name: str, payload: dict) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def mbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-9) / 1e6


def rpc_summary(cl: Cluster, top: int = 8) -> dict:
    """Per-method RPC fabric stats from the typed dispatch table, for the
    benchmark reports: calls, megabytes on the wire, summed virtual-time
    latency — the `top` busiest methods by call count."""
    rows = sorted(cl.rpc_stats().items(), key=lambda kv: -kv[1]["calls"])
    return {m: {"calls": int(v["calls"]),
                "mbytes": round(v["bytes"] / 1e6, 3),
                "vtime_s": round(v["vtime"], 6),
                "timeouts": int(v["timeouts"]),
                "errors": int(v["errors"])}
            for m, v in rows[:top]}
