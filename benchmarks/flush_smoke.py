"""Flush-bench smoke: pipelined drain of 256 dirty files vs a checked-in
virtual-time baseline.

Run by `scripts/check.sh` as a perf regression gate: the background flusher
drains 256 dirty files (64–256 KiB each) on a 6-node cluster and the virtual
drain time is compared against `reports/bench/flush_smoke_baseline.json`.
A >20% regression fails the check (exit 1).

    PYTHONPATH=src python -m benchmarks.flush_smoke --check
    PYTHONPATH=src python -m benchmarks.flush_smoke --update-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile

import numpy as np

from .common import REPORT_DIR, blob, make_cluster, make_fs, save_report

N_FILES = 256
N_NODES = 6
N_DIRS = 8
REGRESSION_TOLERANCE = 0.20

BASELINE_PATH = os.path.join(REPORT_DIR, "flush_smoke_baseline.json")


def run(quiet: bool = False) -> dict:
    wd = tempfile.mkdtemp(prefix="bench-flush-smoke-")
    cl = make_cluster(wd, n=N_NODES)
    fs = make_fs(cl)
    rng = np.random.default_rng(42)
    total = 0
    for d in range(N_DIRS):
        fs.makedirs(f"/bench/d{d}")
    for i in range(N_FILES):
        sz = int(rng.integers(64, 256)) << 10
        total += sz
        fs.write_file(f"/bench/d{i % N_DIRS}/f{i}.bin", blob(sz, i))
    t0 = cl.clock.now
    flushed = cl.drain_dirty(max_rounds=32)
    drain_s = cl.clock.now - t0
    rep = {
        "files": N_FILES,
        "nodes": N_NODES,
        "total_mb": round(total / 1e6, 1),
        "drain_s": round(drain_s, 6),
        "flushed": flushed,
        "flusher": cl.flusher.stats(),
    }
    cl.close()
    shutil.rmtree(wd, ignore_errors=True)
    save_report("flush_smoke", rep)
    if not quiet:
        print(f"[flush-smoke] drained {flushed} files "
              f"({rep['total_mb']} MB) in {drain_s:.3f} virtual s")
    return rep


def check(rep: dict) -> int:
    if not os.path.exists(BASELINE_PATH):
        print(f"[flush-smoke] no baseline at {BASELINE_PATH}; "
              "run --update-baseline first", file=sys.stderr)
        return 1
    with open(BASELINE_PATH) as f:
        base = json.load(f)
    limit = base["drain_s"] * (1.0 + REGRESSION_TOLERANCE)
    if rep["drain_s"] > limit:
        print(f"[flush-smoke] REGRESSION: drain {rep['drain_s']:.3f}s > "
              f"{limit:.3f}s (baseline {base['drain_s']:.3f}s "
              f"+{REGRESSION_TOLERANCE:.0%})", file=sys.stderr)
        return 1
    print(f"[flush-smoke] OK: drain {rep['drain_s']:.3f}s within "
          f"{REGRESSION_TOLERANCE:.0%} of baseline {base['drain_s']:.3f}s")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if drain time regresses >20%% vs baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="record the current drain time as the baseline")
    args = ap.parse_args()
    rep = run()
    if args.update_baseline:
        os.makedirs(REPORT_DIR, exist_ok=True)
        with open(BASELINE_PATH, "w") as f:
            json.dump({"files": rep["files"], "nodes": rep["nodes"],
                       "drain_s": rep["drain_s"]}, f, indent=1)
        print(f"[flush-smoke] baseline updated: {rep['drain_s']:.3f}s")
        return 0
    if args.check:
        return check(rep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
