"""Flush-bench smoke: pipelined drain of 256 dirty files vs a checked-in
virtual-time baseline.

Run by `scripts/check.sh` as a perf regression gate: the background flusher
drains 256 dirty files (64–256 KiB each) on a 6-node cluster and the virtual
drain time is compared against `reports/bench/flush_smoke_baseline.json`.
A >20% regression fails the check (exit 1).

    PYTHONPATH=src python -m benchmarks.flush_smoke --check
    PYTHONPATH=src python -m benchmarks.flush_smoke --update-baseline
"""

from __future__ import annotations

import sys

import numpy as np

from .common import Gate, bench_env, blob, gate_main, make_fs, save_report

N_FILES = 256
N_NODES = 6
N_DIRS = 8

GATES = [Gate("drain_s")]


def run(quiet: bool = False) -> dict:
    with bench_env("bench-flush-smoke-", n=N_NODES) as cl:
        fs = make_fs(cl)
        rng = np.random.default_rng(42)
        total = 0
        for d in range(N_DIRS):
            fs.makedirs(f"/bench/d{d}")
        for i in range(N_FILES):
            sz = int(rng.integers(64, 256)) << 10
            total += sz
            fs.write_file(f"/bench/d{i % N_DIRS}/f{i}.bin", blob(sz, i))
        t0 = cl.clock.now
        flushed = cl.drain_dirty(max_rounds=32)
        drain_s = cl.clock.now - t0
        rep = {
            "files": N_FILES,
            "nodes": N_NODES,
            "total_mb": round(total / 1e6, 1),
            "drain_s": round(drain_s, 6),
            "flushed": flushed,
            "flusher": cl.flusher.stats(),
        }
    save_report("flush_smoke", rep)
    if not quiet:
        print(f"[flush-smoke] drained {flushed} files "
              f"({rep['total_mb']} MB) in {drain_s:.3f} virtual s")
    return rep


def main() -> int:
    return gate_main("flush-smoke", run, "flush_smoke_baseline.json", GATES,
                     baseline_keys=["files", "nodes", "drain_s"])


if __name__ == "__main__":
    sys.exit(main())
