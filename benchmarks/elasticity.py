"""Figs. 13/14: elastic scaling times and migration volumes.

Paper: 1→36 node scale-up then 36→0 scale-down, with 1024 dirty files (1–8
MB, 4.6 GB total) under 32 directories vs without dirty files.  Claims:
scale-up 2–14 s/node with dirty data (first additions slowest), scale-down
2–6.8 s/node; ≤2 s and <1 s respectively when clean; zero-scale of the last
node ~20 ms.  Scaled here: 12 nodes, 128 files of 64–512 KB under 8 dirs."""

from __future__ import annotations

import shutil
import tempfile

import numpy as np

from .common import blob, fastpath_section, make_cluster, make_fs, \
    rpc_summary, save_report, tier_sweep_section

N_NODES = 12
N_FILES = 128
N_DIRS = 8


def _write_dirty(cl, fs):
    rng = np.random.default_rng(0)
    for i in range(N_FILES):
        sz = int(rng.integers(64, 512)) << 10
        fs.write_file(f"/bench/d{i % N_DIRS}/f{i}.bin", blob(sz, i))


def _mkdirs(fs):
    for d in range(N_DIRS):
        fs.makedirs(f"/bench/d{d}")


def _drain_1024(rep: dict, quiet: bool) -> None:
    """Paper-scale dirty-drain (1024 files, §5.2): serial `coord_persist`
    chain vs the pipelined background flusher, same data both times."""
    n_files = 1024
    out: dict = {"files": n_files}
    for mode in ("serial", "pipelined"):
        wd = tempfile.mkdtemp(prefix=f"bench-drain-{mode}-")
        cl = make_cluster(wd, n=8)
        fs = make_fs(cl)
        _mkdirs(fs)
        rng = np.random.default_rng(1)
        total = 0
        for i in range(n_files):
            sz = int(rng.integers(64, 512)) << 10
            total += sz
            fs.write_file(f"/bench/d{i % N_DIRS}/f{i}.bin", blob(sz, i))
        t0 = cl.clock.now
        cl.drain_dirty(serial=(mode == "serial"), max_rounds=64)
        out[f"{mode}_s"] = round(cl.clock.now - t0, 6)
        if mode == "pipelined":
            out["flusher"] = cl.flusher.stats()
        out["total_mb"] = round(total / 1e6, 1)
        cl.close()
        shutil.rmtree(wd, ignore_errors=True)
    out["speedup"] = round(out["serial_s"] / max(out["pipelined_s"], 1e-9), 2)
    rep["drain_1024"] = out
    if not quiet:
        print(f"[fig12+] drain 1024 dirty files ({out['total_mb']} MB): "
              f"serial {out['serial_s']:.2f}s -> pipelined "
              f"{out['pipelined_s']:.2f}s ({out['speedup']}x)")


def run(quiet: bool = False) -> dict:
    rep: dict = {}
    # ---- scale UP with dirty files ---------------------------------------
    wd = tempfile.mkdtemp(prefix="bench-f13a-")
    cl = make_cluster(wd, n=1)
    fs = make_fs(cl)
    _mkdirs(fs)
    _write_dirty(cl, fs)
    ups, migs = [], []
    for _ in range(N_NODES - 1):
        st = cl.add_node()
        ups.append(st.duration)
        migs.append({"metas": st.migrated_metas, "dirs": st.migrated_dirs,
                     "chunks": st.migrated_chunks,
                     "bytes": st.migrated_bytes})
    rep["scale_up_dirty_s"] = ups
    rep["migration_per_join"] = migs
    # ---- scale DOWN with dirty files (write fresh dirty data first) ------
    fs.client._pull_node_list()
    _write_dirty(cl, fs)
    downs = []
    for nm in list(cl.node_list()):
        st = cl.remove_node(nm)
        downs.append(st.duration)
    rep["scale_down_dirty_s"] = downs
    rep["zero_scale_last_s"] = downs[-1]
    # migration/persist traffic breakdown from the typed RPC fabric
    rep["rpc_methods"] = rpc_summary(cl)
    cl.close()
    shutil.rmtree(wd, ignore_errors=True)

    # ---- scale UP/DOWN without dirty files --------------------------------
    wd = tempfile.mkdtemp(prefix="bench-f13b-")
    cl = make_cluster(wd, n=1)
    ups_clean = [cl.add_node().duration for _ in range(N_NODES - 1)]
    downs_clean = [cl.remove_node(nm).duration
                   for nm in list(cl.node_list())]
    rep["scale_up_clean_s"] = ups_clean
    rep["scale_down_clean_s"] = downs_clean
    cl.close()
    shutil.rmtree(wd, ignore_errors=True)

    rep["trend_first_join_slowest"] = ups[0] >= max(ups[1:]) * 0.8
    rep["trend_clean_faster"] = (sum(ups_clean) < sum(ups)
                                 and sum(downs_clean) < sum(downs))

    # ---- before/after: serial vs pipelined drain of 1024 dirty files ------
    _drain_1024(rep, quiet)
    # ---- before/after: metadata fast paths (leases + batching), with one
    # node join so the migration meta-handoff coalescing is visible ---------
    rep["fastpath"] = fastpath_section(n_nodes=6, n_dirs=8, migrate=True)
    # ---- cold/warm/hot read sweep over a tiered (NVMe-over-COS) mount -----
    # elasticity angle: the tier backend outlives cluster generations, so a
    # scale-to-zero + restart pays warm NVMe reads instead of cold COS GETs
    rep["tier_sweep"] = tier_sweep_section(n_nodes=6, n_files=16)
    if not quiet:
        ts = rep["tier_sweep"]
        print(f"[tier] cold {ts['cold_s']:.3f}s -> warm {ts['warm_s']:.3f}s "
              f"({ts['warm_speedup']}x) -> hot {ts['hot_s']:.3f}s "
              f"({ts['hot_speedup']}x) | promotions "
              f"{ts['tier']['promotions']}")
    save_report("fig13_14_elasticity", rep)
    if not quiet:
        print(f"[fig13] up-dirty   "
              + " ".join(f"{u * 1000:6.0f}ms" for u in ups))
        print(f"[fig13] down-dirty "
              + " ".join(f"{d * 1000:6.0f}ms" for d in downs))
        print(f"[fig13] up-clean   "
              + " ".join(f"{u * 1000:6.0f}ms" for u in ups_clean))
        print(f"[fig13] down-clean "
              + " ".join(f"{d * 1000:6.0f}ms" for d in downs_clean))
        m0 = migs[0]
        print(f"[fig14] first join migrated: {m0['metas']} metas, "
              f"{m0['dirs']} dirs, {m0['chunks']} chunks, "
              f"{m0['bytes'] >> 20} MiB | first-join-slowest="
              f"{rep['trend_first_join_slowest']} clean-faster="
              f"{rep['trend_clean_faster']}")
    return rep


if __name__ == "__main__":
    run()
