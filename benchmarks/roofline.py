"""Roofline analysis from the dry-run compiled artifacts (§Roofline).

For every (arch × shape × mesh) record under a dry-run report dir:

    compute term    = FLOPs/device            / 197 TFLOP/s (bf16, v5e)
    memory term     = bytes_accessed/device   / 819 GB/s HBM
    collective term = collective bytes/device / 50 GB/s ICI per link

(cost_analysis and the parsed HLO are the per-device SPMD module, so terms
are per-chip by construction.)

XLA counts a while-loop body ONCE, so scanned models underreport: when a
calibration record exists (repro.launch.calibrate two-point extrapolation),
its corrected flops/bytes/collectives replace the scanned numbers.

MODEL_FLOPS = 6·N·D for training (2·N·D fwd-only), N = active params, D =
tokens processed; useful = MODEL_FLOPS / (HLO_FLOPs × chips) exposes
remat/redundancy waste; roofline fraction = useful model FLOPs per
chip-second at the dominant bound / peak.

    PYTHONPATH=src python -m benchmarks.roofline [--dryrun-dir ...]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12       # bf16 per chip (TPU v5e)
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_BASE = os.path.join(os.path.dirname(__file__), "..", "reports")
DRYRUN_DIR = os.path.join(_BASE, "dryrun")
CAL_DIR = os.path.join(_BASE, "calibration")
OUT_MD = os.path.join(_BASE, "roofline.md")
OUT_JSON = os.path.join(_BASE, "roofline.json")


def model_flops(rec: dict) -> float:
    n = rec["params_active"]
    if rec["kind"] == "train":
        return 6.0 * n * rec["global_batch"] * rec["seq_len"]
    if rec["kind"] == "prefill":
        return 2.0 * n * rec["global_batch"] * rec["seq_len"]
    return 2.0 * n * rec["global_batch"]      # decode: one token/request


_SUGGEST = {
    "compute": "compute-bound: wins come from cutting redundant FLOPs "
               "(remat policy, fused attention) or faster kernels",
    "memory": "cut HBM traffic: bigger fusion regions, bf16 activations, "
              "remat policy, flash attention to avoid score "
              "materialization",
    "collective": "reshard to shrink all-gather/all-reduce volume: "
                  "sequence-parallel residuals, grouped MoE dispatch, "
                  "reduce-scatter gradients, overlap with compute",
}


def _load_calibration(cal_dir: str) -> dict:
    out = {}
    for path in glob.glob(os.path.join(cal_dir, "*.json")):
        rec = json.load(open(path))
        out[(rec["arch"], rec["shape"])] = rec["corrected"]
    return out


def analyse(rec: dict, cal: dict | None) -> dict:
    pd = rec["per_device"]
    flops = pd["flops"]
    nbytes = pd["bytes_accessed"]
    coll = pd["collective_bytes"]["total"]
    calibrated = False
    if cal is not None:
        flops, nbytes = cal["flops"], cal["bytes"]
        coll = cal["collective"]["total"]
        calibrated = True
    t_compute = flops / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_time = max(terms.values())
    mf = model_flops(rec)
    hlo_total = flops * rec["n_devices"]
    useful = mf / hlo_total if hlo_total else 0.0
    mfu_bound = (mf / rec["n_devices"]) / max(step_time, 1e-12) / PEAK_FLOPS
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "calibrated": calibrated,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": mfu_bound,
        "temp_gib": pd["temp_bytes"] / 2**30,
        "suggestion": _SUGGEST[dominant],
    }


def run(quiet: bool = False, mesh: str = "16x16",
        dryrun_dir: str = DRYRUN_DIR, cal_dir: str = CAL_DIR,
        out_md: str = OUT_MD, out_json: str = OUT_JSON,
        title: str = "Roofline") -> list[dict]:
    cals = _load_calibration(cal_dir) if os.path.isdir(cal_dir) else {}
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(analyse(rec, cals.get((rec["arch"], rec["shape"]))))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        f"# {title} (single-pod 16x16, per-chip terms; v5e: 197 TF bf16, "
        "819 GB/s HBM, 50 GB/s ICI)",
        "",
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " useful | roofline frac | temp GiB | cal |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} "
            f"| {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {r['temp_gib']:.1f} "
            f"| {'y' if r['calibrated'] else 'n'} |")
    os.makedirs(os.path.dirname(out_md), exist_ok=True)
    with open(out_md, "w") as f:
        f.write("\n".join(lines) + "\n")
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    if not quiet:
        for r in rows:
            print(f"[roofline] {r['arch']:18s} {r['shape']:12s} "
                  f"dom={r['dominant']:10s} "
                  f"frac={r['roofline_fraction']:7.3f} "
                  f"useful={r['useful_ratio']:5.2f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default=DRYRUN_DIR)
    ap.add_argument("--cal-dir", default=CAL_DIR)
    ap.add_argument("--out-md", default=OUT_MD)
    ap.add_argument("--out-json", default=OUT_JSON)
    ap.add_argument("--title", default="Roofline")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    run(dryrun_dir=args.dryrun_dir, cal_dir=args.cal_dir,
        out_md=args.out_md, out_json=args.out_json, title=args.title,
        mesh=args.mesh)


if __name__ == "__main__":
    main()
