"""Fig. 11: model-serving startup time — direct S3 copy vs S3FS vs objcache
(miss / cluster-hit / node-hit).

Paper: T5-11B as 464 files, 43 GB (scaled here to 64 files, 256 MB).
Claim: objcache node-hit cuts startup 98.9% vs direct S3; S3FS beats an
objcache cold miss slightly but cannot share across nodes."""

from __future__ import annotations

import shutil
import tempfile

from repro.baselines import S3Direct, S3FSConfig, S3FSLike

from .common import CHUNK, blob, make_cluster, make_fs, save_report

N_FILES = 64
FILE_SZ = 4 << 20     # 4 MiB each → 256 MiB model


def _publish_model(cos):
    for i in range(N_FILES):
        cos.put_object("bench", f"model/w{i:03d}.bin", blob(FILE_SZ, i))


def _load_via(read_file, names, clock):
    t0 = clock.now
    total = 0
    for nm in names:
        total += len(read_file(nm))
    return clock.now - t0, total


def run(quiet: bool = False) -> dict:
    wd = tempfile.mkdtemp(prefix="bench-f11-")
    try:
        cl = make_cluster(wd, n=4)
        _publish_model(cl.cos)
        names = [f"model/w{i:03d}.bin" for i in range(N_FILES)]

        # direct S3: download to local disk, then read the staging copy
        s3 = S3Direct(cl.cos, "bench", cl.clock)
        t0 = cl.clock.now
        for nm in names:
            s3.download(nm)
            s3.read_local(nm)
        t_s3 = cl.clock.now - t0

        # S3FS wrapper (16 MB chunks per §6.3 → scaled 1 MB)
        s3fs = S3FSLike(cl.cos, "bench", cl.clock,
                        cfg=S3FSConfig(chunk_size=CHUNK, parallel=64,
                                       prefetch_bytes=FILE_SZ))
        t_s3fs, _ = _load_via(s3fs.read_file, names, cl.clock)

        # objcache: cold miss, cluster hit (another node), node hit (again)
        fs1 = make_fs(cl, consistency="weak", readahead=64)
        t_miss, _ = _load_via(
            lambda nm: fs1.read_file("/bench/" + nm), names, cl.clock)
        fs2 = make_fs(cl, consistency="weak", node=cl.node_list()[1],
                      readahead=16)
        t_cluster, _ = _load_via(
            lambda nm: fs2.read_file("/bench/" + nm), names, cl.clock)
        t_node, _ = _load_via(
            lambda nm: fs2.read_file("/bench/" + nm), names, cl.clock)

        rep = {
            "n_files": N_FILES, "model_mb": N_FILES * FILE_SZ >> 20,
            "s3_direct_s": t_s3, "s3fs_s": t_s3fs,
            "objcache_miss_s": t_miss, "objcache_cluster_s": t_cluster,
            "objcache_node_s": t_node,
            "node_vs_s3_direct_pct": 100 * (1 - t_node / t_s3),
            "cluster_vs_s3_direct_pct": 100 * (1 - t_cluster / t_s3),
        }
        save_report("fig11_serving_startup", rep)
        if not quiet:
            print(f"[fig11] s3={t_s3:7.2f}s s3fs={t_s3fs:7.2f}s "
                  f"miss={t_miss:7.2f}s cluster={t_cluster:7.2f}s "
                  f"node={t_node:7.2f}s | node cut vs s3: "
                  f"{rep['node_vs_s3_direct_pct']:.1f}% (paper: 98.9%)")
        cl.close()
        return rep
    finally:
        shutil.rmtree(wd, ignore_errors=True)


if __name__ == "__main__":
    run()
