"""Fig. 9: sequential read throughput — miss vs cluster-hit vs node-hit,
against S3FS wrapping the same bucket.

Paper claim: cluster/node cache hits are 193%–1115% faster than S3FS;
misses are up to 27% slower (detached networking overhead)."""

from __future__ import annotations

import shutil
import tempfile

from repro.baselines import S3FSConfig, S3FSLike

from .common import CHUNK, FILE_MB, blob, fastpath_section, make_cluster, \
    make_fs, mbps, rpc_summary, save_report

BLOCK = 128 * 1024


def _seq_read(fs, path, size, clock):
    t0 = clock.now
    fh = fs.open(path, "r")
    pos = 0
    while pos < size:
        n = len(fs.read(fh, pos, BLOCK))
        if n == 0:
            break
        pos += n
    fs.close(fh)
    return clock.now - t0


def run(quiet: bool = False) -> dict:
    wd = tempfile.mkdtemp(prefix="bench-fio-")
    size = FILE_MB << 20
    data = blob(size, 1)
    try:
        cl = make_cluster(wd, n=4)
        cl.cos.put_object("bench", "big.bin", data)

        # S3FS baseline (same COS, page cache on, 52MB-chunk equivalent)
        s3fs = S3FSLike(cl.cos, "bench", cl.clock,
                        cfg=S3FSConfig(chunk_size=52 * CHUNK // 16,
                                       prefetch_bytes=size))
        t0 = cl.clock.now
        s3fs.read_file("big.bin")
        t_s3fs_cold = cl.clock.now - t0
        t0 = cl.clock.now
        s3fs.read_file("big.bin")
        t_s3fs_warm = cl.clock.now - t0

        # paper config: 1 GB external prefetch / 16 MB chunks = 64 chunks
        fs = make_fs(cl, consistency="weak", readahead=64)
        t_miss = _seq_read(fs, "/bench/big.bin", size, cl.clock)   # COS miss
        # cluster hit: a different node's client, no page cache yet
        # (paper: 256 MB cluster-local prefetch = 16 chunks scaled)
        fs2 = make_fs(cl, consistency="weak", node=cl.node_list()[1],
                      readahead=16)
        t_cluster = _seq_read(fs2, "/bench/big.bin", size, cl.clock)
        # node hit: same client again (node-local page cache)
        t_node = _seq_read(fs2, "/bench/big.bin", size, cl.clock)

        rep = {
            "file_mb": FILE_MB,
            "s3fs_cold_mbps": mbps(size, t_s3fs_cold),
            "s3fs_warm_mbps": mbps(size, t_s3fs_warm),
            "objcache_miss_mbps": mbps(size, t_miss),
            "objcache_cluster_mbps": mbps(size, t_cluster),
            "objcache_node_mbps": mbps(size, t_node),
        }
        rep["cluster_vs_s3fs_pct"] = 100 * (
            rep["objcache_cluster_mbps"] / rep["s3fs_cold_mbps"] - 1)
        rep["node_vs_s3fs_pct"] = 100 * (
            rep["objcache_node_mbps"] / rep["s3fs_cold_mbps"] - 1)
        rep["miss_vs_s3fs_pct"] = 100 * (
            rep["objcache_miss_mbps"] / rep["s3fs_cold_mbps"] - 1)
        rep["rpc_methods"] = rpc_summary(cl)
        # before/after the PR 7 metadata fast paths (leases + batching) on
        # the metadata side traffic of the same cluster shape
        rep["fastpath"] = fastpath_section(n_nodes=4)
        save_report("fig9_fio_seqread", rep)
        if not quiet:
            busiest = next(iter(rep["rpc_methods"]), None)
            if busiest:
                b = rep["rpc_methods"][busiest]
                print(f"[fig9] busiest rpc: {busiest} x{b['calls']} "
                      f"({b['mbytes']:.1f} MB, {b['vtime_s']:.3f}s vtime)")
            print(f"[fig9] s3fs {rep['s3fs_cold_mbps']:8.1f} MB/s | "
                  f"miss {rep['objcache_miss_mbps']:8.1f} "
                  f"({rep['miss_vs_s3fs_pct']:+.0f}%) | "
                  f"cluster {rep['objcache_cluster_mbps']:8.1f} "
                  f"({rep['cluster_vs_s3fs_pct']:+.0f}%) | "
                  f"node {rep['objcache_node_mbps']:8.1f} "
                  f"({rep['node_vs_s3fs_pct']:+.0f}%)")
        cl.close()
        return rep
    finally:
        shutil.rmtree(wd, ignore_errors=True)


if __name__ == "__main__":
    run()
