"""Fig. 12: training-workload storage I/O — model load + periodic
checkpointing, S3FS vs objcache.

Paper: T5-XXL fine-tune on 4 nodes; objcache cut model-load time 24% (four
nodes deduplicate the download) and checkpoint time 274% (asynchronous
write-back overlaps GPU compute; S3FS uploads synchronously at close)."""

from __future__ import annotations

import shutil
import tempfile

from repro.baselines import S3FSConfig, S3FSLike
from repro.core import SimClock

from .common import CHUNK, blob, make_cluster, make_fs, make_tier, \
    save_report

MODEL_MB = 128          # paper: 42 GB; scaled
CKPT_MB = 32            # per checkpoint
N_NODES = 4
N_ITERS = 16            # paper: 128 iterations
CKPT_EVERY = 4          # paper: every 32
ITER_S = 0.5            # virtual GPU compute per iteration


def _run_objcache(wd):
    cl = make_cluster(wd, n=N_NODES)
    cl.cos.put_object("bench", "model.bin", blob(MODEL_MB << 20, 1))
    # 4 workers (one per node) load the model in parallel — cluster cache
    # deduplicates the COS download across nodes
    t_load = _parallel_load(cl, cl.clock.now)

    fs = make_fs(cl, consistency="weak", readahead=16)
    ckpt_blocked = 0.0
    t_train0 = cl.clock.now
    for it in range(N_ITERS):
        cl.clock.sleep(ITER_S)            # GPU compute
        if (it + 1) % CKPT_EVERY == 0:
            t0 = cl.clock.now
            fs.write_file(f"/bench/ckpt_{it}.bin", blob(CKPT_MB << 20, it))
            ckpt_blocked += cl.clock.now - t0   # commit to cluster cache
            cl.tick_flush(max_inodes=4)         # async upload (overlapped)
    cl.drain_dirty()
    total = cl.clock.now - t_train0
    cl.close()
    return t_load, ckpt_blocked, total


def _run_s3fs(wd):
    cl = make_cluster(wd, n=N_NODES)
    cl.cos.put_object("bench", "model.bin", blob(MODEL_MB << 20, 1))
    # every node pays its own download (no sharing)
    t0 = cl.clock.now
    ends = []
    for i in range(N_NODES):
        s3fs = S3FSLike(cl.cos, "bench", cl.clock, node=f"n{i}",
                        cfg=S3FSConfig(chunk_size=CHUNK, parallel=64,
                                       prefetch_bytes=MODEL_MB << 20))
        cl.clock.now = t0
        s3fs.read_file("model.bin")
        ends.append(cl.clock.now)
    cl.clock.advance_to(max(ends))
    t_load = max(ends) - t0

    s3fs = S3FSLike(cl.cos, "bench", cl.clock,
                    cfg=S3FSConfig(chunk_size=CHUNK, parallel=64))
    ckpt_blocked = 0.0
    t_train0 = cl.clock.now
    for it in range(N_ITERS):
        cl.clock.sleep(ITER_S)
        if (it + 1) % CKPT_EVERY == 0:
            t0 = cl.clock.now
            s3fs.write_file(f"ckpt_{it}.bin", blob(CKPT_MB << 20, it))
            ckpt_blocked += cl.clock.now - t0   # synchronous upload at close
    total = cl.clock.now - t_train0
    cl.close()
    return t_load, ckpt_blocked, total


def _parallel_load(cl, t0):
    """All nodes read the model starting together; returns the makespan."""
    ends = []
    for node in cl.node_list():
        fs = make_fs(cl, consistency="weak", node=node, readahead=64)
        cl.clock.now = t0
        fs.read_file("/bench/model.bin")
        ends.append(cl.clock.now)
    cl.clock.advance_to(max(ends))
    return max(ends) - t0


def _run_tiered_load():
    """Model load over a tiered bucket mount (NVMe cache over the S3-like
    base), cold vs warm: the first job's parallel load pulls the model from
    the base and promotes it into the NVMe tier; a second job generation
    (fresh cluster, same backends) loads it from NVMe instead of COS — the
    restart-a-training-job case where the tier pays for itself."""
    clock = SimClock()
    tier = make_tier(clock, nvme_mb=256, promote_min_hits=2)
    tier.base.put_object("bench", "model.bin", blob(MODEL_MB << 20, 1))
    loads = {}
    for phase in ("cold", "warm"):
        wd = tempfile.mkdtemp(prefix=f"bench-f12-tier-{phase}-")
        try:
            cl = make_cluster(wd, n=N_NODES, backends={"tiered": tier},
                              backend="tiered", clock=clock)
            loads[phase] = _parallel_load(cl, cl.clock.now)
            cl.close()
        finally:
            shutil.rmtree(wd, ignore_errors=True)
    return {
        "cold_load_s": round(loads["cold"], 6),
        "warm_load_s": round(loads["warm"], 6),
        "warm_speedup": round(loads["cold"] / max(loads["warm"], 1e-9), 2),
        "tier": tier.stats(),
    }


def run(quiet: bool = False) -> dict:
    wd1 = tempfile.mkdtemp(prefix="bench-f12a-")
    wd2 = tempfile.mkdtemp(prefix="bench-f12b-")
    try:
        oc_load, oc_ckpt, oc_total = _run_objcache(wd1)
        s3_load, s3_ckpt, s3_total = _run_s3fs(wd2)
        rep = {
            "objcache": {"load_s": oc_load, "ckpt_blocked_s": oc_ckpt,
                         "total_s": oc_total},
            "s3fs": {"load_s": s3_load, "ckpt_blocked_s": s3_ckpt,
                     "total_s": s3_total},
            "load_speedup_pct": 100 * (s3_load / oc_load - 1),
            "ckpt_speedup_pct": 100 * (s3_ckpt / max(oc_ckpt, 1e-9) - 1),
            "tiered_load": _run_tiered_load(),
        }
        save_report("fig12_training_io", rep)
        if not quiet:
            print(f"[fig12] load: s3fs={s3_load:6.2f}s oc={oc_load:6.2f}s "
                  f"(+{rep['load_speedup_pct']:.0f}%, paper +24%) | "
                  f"ckpt-blocked: s3fs={s3_ckpt:6.2f}s oc={oc_ckpt:6.2f}s "
                  f"(+{rep['ckpt_speedup_pct']:.0f}%, paper +274%)")
            tl = rep["tiered_load"]
            print(f"[fig12] tiered model load: cold {tl['cold_load_s']:.2f}s "
                  f"-> warm {tl['warm_load_s']:.2f}s "
                  f"({tl['warm_speedup']}x, NVMe tier across job restarts)")
        return rep
    finally:
        shutil.rmtree(wd1, ignore_errors=True)
        shutil.rmtree(wd2, ignore_errors=True)


if __name__ == "__main__":
    run()
