"""Fig. 10: strict (read-after-write) vs weak (close-to-open) consistency ×
embedded vs detached deployment — sequential/random write, sequential/random
read, and write+fsync throughput while scaling cache servers.

Paper claims: weak wins on writes (buffering/batching); strict wins on
random reads (no client cache management); embedded generally beats detached
(no local hop)."""

from __future__ import annotations

import shutil
import tempfile

import numpy as np

from .common import CHUNK, blob, make_cluster, make_fs, mbps, save_report

FILE = 8 << 20           # 8 MiB per thread (paper: 1 GiB; scaled)
BLOCK = 128 * 1024       # paper's FIO block size


def _seq_write(fs, path, clock):
    data = blob(FILE, 7)
    t0 = clock.now
    fh = fs.open(path, "w")
    for off in range(0, FILE, BLOCK):
        fs.write(fh, off, data[off:off + BLOCK])
    fs.close(fh)
    return mbps(FILE, clock.now - t0)


def _rand_write(fs, path, clock):
    data = blob(FILE, 8)
    order = np.random.default_rng(1).permutation(FILE // BLOCK)
    t0 = clock.now
    fh = fs.open(path, "w")
    for i in order:
        off = int(i) * BLOCK
        fs.write(fh, off, data[off:off + BLOCK])
    fs.close(fh)
    return mbps(FILE, clock.now - t0)


def _seq_read(fs, path, clock):
    t0 = clock.now
    fh = fs.open(path, "r")
    for off in range(0, FILE, BLOCK):
        fs.read(fh, off, BLOCK)
    fs.close(fh)
    return mbps(FILE, clock.now - t0)


def _rand_read(fs, path, clock):
    order = np.random.default_rng(2).permutation(FILE // BLOCK)
    t0 = clock.now
    fh = fs.open(path, "r")
    for i in order:
        fs.read(fh, int(i) * BLOCK, BLOCK)
    fs.close(fh)
    return mbps(FILE, clock.now - t0)


def _write_fsync(fs, path, clock):
    data = blob(FILE, 9)
    t0 = clock.now
    fh = fs.open(path, "w")
    for off in range(0, FILE, BLOCK):
        fs.write(fh, off, data[off:off + BLOCK])
    fs.fsync(fh)
    fs.close(fh)
    return mbps(FILE, clock.now - t0)


def run(quiet: bool = False, nodes=(1, 2, 4, 8)) -> dict:
    out: dict = {"nodes": list(nodes), "cells": {}}
    for n in nodes:
        for consistency in ("strict", "weak"):
            for deployment in ("embedded", "detached"):
                wd = tempfile.mkdtemp(prefix="bench-f10-")
                try:
                    cl = make_cluster(wd, n=n)
                    # cold read targets (no cache fill)
                    cl.cos.put_object("bench", "sr.bin", blob(FILE, 3))
                    cl.cos.put_object("bench", "rr.bin", blob(FILE, 4))
                    fs = make_fs(cl, consistency=consistency,
                                 deployment=deployment)
                    cell = {
                        "seq_write": _seq_write(fs, "/bench/w.bin",
                                                cl.clock),
                        "rand_write": _rand_write(fs, "/bench/rw.bin",
                                                  cl.clock),
                        "seq_read": _seq_read(fs, "/bench/sr.bin",
                                              cl.clock),
                        "rand_read": _rand_read(fs, "/bench/rr.bin",
                                                cl.clock),
                        "write_fsync": _write_fsync(fs, "/bench/wf.bin",
                                                    cl.clock),
                    }
                    out["cells"][f"{consistency}/{deployment}/n{n}"] = cell
                    cl.close()
                finally:
                    shutil.rmtree(wd, ignore_errors=True)
    # paper-trend checks at the largest size
    n = nodes[-1]
    sw = {c: out["cells"][f"{c}/detached/n{n}"]["seq_write"]
          for c in ("strict", "weak")}
    rr = {c: out["cells"][f"{c}/detached/n{n}"]["rand_read"]
          for c in ("strict", "weak")}
    out["trend_weak_write_faster"] = sw["weak"] > sw["strict"]
    out["trend_strict_randread_not_slower"] = rr["strict"] >= rr["weak"] * 0.9
    save_report("fig10_consistency_models", out)
    if not quiet:
        for k, v in out["cells"].items():
            print(f"[fig10] {k:24s} " + "  ".join(
                f"{m}={x:9.1f}MB/s" for m, x in v.items()))
        print(f"[fig10] weak-write-faster={out['trend_weak_write_faster']} "
              f"strict-randread-ok={out['trend_strict_randread_not_slower']}")
    return out


if __name__ == "__main__":
    run()
