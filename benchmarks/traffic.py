"""Open-loop multi-tenant traffic sweep with and without QoS admission.

The closed-loop benchmarks (`multi_tenant.py`, the Fig-9 reproductions)
cannot show queueing collapse: a slow cluster throttles its own offered
load.  This sweep drives the cluster *open-loop* (`repro.core.loadgen`) at
fixed offered rates spanning the capacity knee, with three tenants:

* `gold`   — contracted interactive class, steady Poisson arrivals;
* `silver` — bursty ON/OFF batch class;
* `best`   — best-effort bulk class offering half the total load.

Each load point runs twice on a fresh cluster: `no_admission` (the fabric
accepts everything — p999 diverges past the knee and every tenant collapses
together) and `admission` (per-tenant token buckets at the Router shed
best-effort overload — gold's p99 stays bounded while shed rate absorbs the
excess).  Results land in `reports/bench/traffic.json` with `knee` and
`qos` summary sections.

    PYTHONPATH=src python -m benchmarks.traffic
"""

from __future__ import annotations

import sys

from repro.core import (OnOffArrivals, OpenLoopRunner, PoissonArrivals,
                        ServerConfig, TenantSpec, build_schedule,
                        default_qos_policy, loadtest_hw, summarize)

from .common import bench_env, make_fs, rpc_summary, save_report

N_NODES = 4
CHUNK = 64 * 1024
HORIZON_S = 2.0
SEED = 20260808
N_DIRS = 8
FILES_PER_DIR = 16
FILE_BYTES = 8192

# Offered-load sweep (total fs-ops/s across tenants).  loadtest_hw() puts
# the capacity knee near ~700 ops/s on 4 nodes: the first two points are
# healthy, 800 queues, 1600 is 2x overload (collapse without admission).
LOAD_POINTS = [200, 400, 800, 1600]
CAPACITY_OPS_S = 600          # admission policy sizing (see default_qos_policy)

# tenant shares of the total offered load
GOLD_SHARE, SILVER_SHARE, BEST_SHARE = 0.25, 0.25, 0.50
# ON/OFF duty cycle: mean rate = on_rate * on / (on + off)
ON_S, OFF_S = 0.2, 0.3
GOLD_P99_BUDGET_MS = 120.0    # the SLO the qos section checks at 2x overload


def make_tenants(total_ops_s: float) -> list[TenantSpec]:
    duty = ON_S / (ON_S + OFF_S)
    return [
        TenantSpec("gold", PoissonArrivals(GOLD_SHARE * total_ops_s),
                   n_clients=512, qos_class="gold"),
        TenantSpec("silver",
                   OnOffArrivals(SILVER_SHARE * total_ops_s / duty,
                                 mean_on_s=ON_S, mean_off_s=OFF_S),
                   n_clients=512, qos_class="silver"),
        TenantSpec("best", PoissonArrivals(BEST_SHARE * total_ops_s),
                   n_clients=1024, qos_class="best"),
    ]


def build_catalog(cl) -> tuple[list[str], list[str]]:
    # fixed boot id: keeps virtual timing identical across the sweep's
    # cells regardless of the process-global client-id counter
    fs = make_fs(cl, consistency="strict", client_id=9001)
    for t in ("gold", "silver", "best"):
        fs.makedirs(f"/bench/{t}")
    dirs, files = [], []
    for d in range(N_DIRS):
        dp = f"/data{d}"
        fs.mkdir(dp)
        dirs.append(dp)
        for i in range(FILES_PER_DIR):
            p = f"{dp}/f{i}.bin"
            fs.write_file(p, bytes(FILE_BYTES))
            files.append(p)
    return files, dirs


def run_point(total_ops_s: float, admission: bool, *, nodes: int = N_NODES,
              horizon_s: float = HORIZON_S, seed: int = SEED,
              capacity_ops_s: float = CAPACITY_OPS_S,
              pool_per_tenant: int = 16) -> dict:
    mode = "admission" if admission else "no_admission"
    with bench_env(f"bench-traffic-{mode}-", n=nodes, chunk=CHUNK,
                   hw=loadtest_hw(),
                   cfg=ServerConfig(chunk_size=CHUNK)) as cl:
        files, dirs = build_catalog(cl)
        tenants = make_tenants(total_ops_s)
        sched = build_schedule(tenants, files, dirs, horizon_s=horizon_s,
                               seed=seed)
        if admission:
            cl.router.set_admission(default_qos_policy(capacity_ops_s))
        runner = OpenLoopRunner(cl, tenants, consistency="strict",
                                pool_per_tenant=pool_per_tenant)
        results = runner.run(sched)
        cell = summarize(results, horizon_s)
        cell["tenant_stats"] = {
            t: {k: round(v, 6) for k, v in st.items()}
            for t, st in sorted(cl.router.tenant_stats.items())}
        cell["rpc_envelopes"] = cl.router.rpc_count
        cell["rpc_methods"] = rpc_summary(cl, top=5)
        return cell


def run(quiet: bool = False) -> dict:
    rep: dict = {
        "nodes": N_NODES, "horizon_s": HORIZON_S, "seed": SEED,
        "capacity_ops_s": CAPACITY_OPS_S,
        "load_points_ops_s": LOAD_POINTS,
        "tenant_shares": {"gold": GOLD_SHARE, "silver": SILVER_SHARE,
                          "best": BEST_SHARE},
        "sweep": [],
    }
    for total in LOAD_POINTS:
        point = {"offered_ops_s": total,
                 "no_admission": run_point(total, admission=False),
                 "admission": run_point(total, admission=True)}
        rep["sweep"].append(point)
        if not quiet:
            na, ad = point["no_admission"], point["admission"]
            print(f"[traffic] {total:5d} ops/s: "
                  f"no-adm p99={na['overall']['p99_ms']:9.3f}ms "
                  f"p999={na['overall']['p999_ms']:9.3f}ms | "
                  f"adm gold p99={ad['tenants']['gold']['p99_ms']:8.3f}ms "
                  f"best shed={ad['tenants']['best']['shed_rate']:.0%}")

    # knee: the load point where open-loop p999 diverges (queueing delay
    # comparable to the whole horizon) without admission
    base = rep["sweep"][0]["no_admission"]["overall"]["p999_ms"]
    knee = None
    for point in rep["sweep"]:
        if point["no_admission"]["overall"]["p999_ms"] > max(10 * base, 100):
            knee = point["offered_ops_s"]
            break
    rep["knee"] = {
        "baseline_p999_ms": base,
        "diverges_at_ops_s": knee,
        "p999_by_load_ms": {str(p["offered_ops_s"]):
                            p["no_admission"]["overall"]["p999_ms"]
                            for p in rep["sweep"]},
    }

    # qos: at the heaviest point (2x overload), admission must keep the
    # contracted class inside its latency budget by shedding best-effort
    last = rep["sweep"][-1]
    gold_adm = last["admission"]["tenants"]["gold"]
    gold_na = last["no_admission"]["tenants"]["gold"]
    best_adm = last["admission"]["tenants"]["best"]
    rep["qos"] = {
        "overload_ops_s": last["offered_ops_s"],
        "gold_p99_budget_ms": GOLD_P99_BUDGET_MS,
        "gold_p99_no_admission_ms": gold_na["p99_ms"],
        "gold_p99_admission_ms": gold_adm["p99_ms"],
        "gold_within_budget": gold_adm["p99_ms"] <= GOLD_P99_BUDGET_MS,
        "gold_shed_rate": gold_adm["shed_rate"],
        "best_shed_rate": best_adm["shed_rate"],
        "jain_no_admission": last["no_admission"]["jain_fairness"],
        "jain_admission": last["admission"]["jain_fairness"],
    }
    save_report("traffic", rep)
    if not quiet:
        q = rep["qos"]
        print(f"[traffic] knee at {rep['knee']['diverges_at_ops_s']} ops/s; "
              f"at {q['overload_ops_s']} ops/s gold p99 "
              f"{q['gold_p99_no_admission_ms']:.1f} -> "
              f"{q['gold_p99_admission_ms']:.1f} ms "
              f"(budget {q['gold_p99_budget_ms']:.0f}), "
              f"best shed {q['best_shed_rate']:.0%}")
    return rep


if __name__ == "__main__":
    sys.exit(0 if run() else 1)
