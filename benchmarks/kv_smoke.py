"""kv_smoke: regression gate for the KV-block data path (no JAX).

Drives `serving.kvstore.KVCacheStore` with synthetic cache-shaped arrays:
snapshot puts through the weak-consistency write path, a drain to COS, and
tiered reads on a scale-to-zero survivor cluster (cold COS / cluster /
node / single-layer ranged read).  Gated metrics (virtual seconds and RPC
envelopes) fail `scripts/check.sh` on a >20% regression vs
``reports/bench/kv_smoke_baseline.json``; refresh with
``python -m benchmarks.kv_smoke --update-baseline`` after an intentional
change (and say why in the commit).
"""

from __future__ import annotations

import shutil
import sys
import tempfile

import numpy as np

from repro.serving.kvstore import KVCacheStore

from .common import Gate, gate_main, make_cluster, make_fs, save_report

N_PER, KV_LEN = 4, 128
PROMPT_LEN, BLOCK = 64, 16


def _cache(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "slot0": {
            "k": rng.standard_normal((N_PER, 1, 2, KV_LEN, 32)
                                     ).astype(np.float16),
            "v": rng.standard_normal((N_PER, 1, 2, KV_LEN, 32)
                                     ).astype(np.float16),
        },
        "slot1": {
            "conv": rng.standard_normal((N_PER, 1, 3, 96)
                                        ).astype(np.float16),
            "ssm": rng.standard_normal((N_PER, 1, 4, 16, 16)
                                       ).astype(np.float32),
        },
    }


def run(quiet: bool = False) -> dict:
    wd1 = tempfile.mkdtemp(prefix="bench-kvs-1-")
    wd2 = tempfile.mkdtemp(prefix="bench-kvs-2-")
    try:
        cl = make_cluster(wd1, n=3)
        fs = make_fs(cl, consistency="weak")
        kv = KVCacheStore(fs, "/bench/kv", block_tokens=BLOCK)
        prompt = np.arange(1000, 1000 + PROMPT_LEN, dtype=np.int32)
        t0 = cl.clock.now
        for ln in kv.snapshot_lens(PROMPT_LEN):       # 16, 32, 48, 63
            kv.put(prompt[:ln], _cache(ln))
        put_s = cl.clock.now - t0
        t0 = cl.clock.now
        cl.drain_dirty()
        drain_s = cl.clock.now - t0

        # scale-to-zero survivor: same COS, empty cluster caches
        cl2 = make_cluster(wd2, n=3)
        cl2.cos = cl.cos
        for s in cl2.servers.values():
            s.cos = cl.cos
        env0 = cl2.router.rpc_count
        like = _cache(0)

        fs_a = make_fs(cl2, consistency="weak")
        kv_a = KVCacheStore(fs_a, "/bench/kv", block_tokens=BLOCK)
        t0 = cl2.clock.now
        ln, key = kv_a.lookup(prompt, cap=PROMPT_LEN - 1)
        cache_a, _ = kv_a.get(key, like=like)
        cold_s = cl2.clock.now - t0

        fs_b = make_fs(cl2, consistency="weak", node=cl2.node_list()[1])
        kv_b = KVCacheStore(fs_b, "/bench/kv", block_tokens=BLOCK)
        t0 = cl2.clock.now
        kv_b.get(kv_b.lookup(prompt, cap=PROMPT_LEN - 1)[1], like=like)
        cluster_s = cl2.clock.now - t0
        t0 = cl2.clock.now
        kv_b.get(key, like=like)
        node_s = cl2.clock.now - t0
        t0 = cl2.clock.now
        layer, _ = kv_b.get(key, layers={"slot0/k"})
        layer_s = cl2.clock.now - t0

        # correctness backstop: the tiers must return the publisher's bytes
        src = _cache(ln)
        np.testing.assert_array_equal(cache_a["slot0"]["k"],
                                      src["slot0"]["k"])
        np.testing.assert_array_equal(layer["slot0"]["k"], src["slot0"]["k"])
        assert ln == PROMPT_LEN - 1

        rep = {
            "prefixes": kv.stats["puts"],
            "put_bytes": kv.stats["put_bytes"],
            "put_s": round(put_s, 6),
            "drain_s": round(drain_s, 6),
            "cold_get_s": round(cold_s, 6),
            "cluster_get_s": round(cluster_s, 6),
            "node_get_s": round(node_s, 6),
            "layer_range_s": round(layer_s, 6),
            "read_envelopes": cl2.router.rpc_count - env0,
            "probes": kv_a.stats["probes"] + kv_b.stats["probes"],
        }
        save_report("kv_smoke", rep)
        if not quiet:
            print(f"[kv_smoke] put={put_s:.4f}s cold={cold_s:.4f}s "
                  f"cluster={cluster_s:.4f}s node={node_s:.4f}s "
                  f"layer={layer_s:.4f}s env={rep['read_envelopes']}")
        cl2.close()
        cl.close()
        return rep
    finally:
        shutil.rmtree(wd1, ignore_errors=True)
        shutil.rmtree(wd2, ignore_errors=True)


GATES = [Gate("put_s"), Gate("cold_get_s"), Gate("node_get_s", slack=1e-4),
         Gate("layer_range_s", slack=1e-4), Gate("read_envelopes")]
BASELINE_KEYS = ["put_s", "cold_get_s", "node_get_s", "layer_range_s",
                 "read_envelopes"]


if __name__ == "__main__":
    sys.exit(gate_main("kv_smoke", lambda: run(quiet=False),
                       "kv_smoke_baseline.json", GATES, BASELINE_KEYS))
