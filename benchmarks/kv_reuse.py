"""KV-cache reuse: time-to-first-token through the cache tiers.

The Fig. 11 methodology applied to *inference state* instead of params
(the serving scenario the paper never measured; ObjectCache, arXiv
2605.22850, is the reference workload shape): a replica persists
per-layer KV blocks keyed by prompt prefix through ObjcacheFS, and a
request's TTFT is measured with those blocks resident in each tier —

* ``cold_cos``       — fresh cluster after a scale-to-zero drain; blocks
                       fetched from external COS;
* ``cluster_cache``  — a second client on another node; blocks are
                       cluster-resident after the cold fetch;
* ``node_cache``     — the same client again; node-local page cache;
* ``exact_hit``      — full-prompt prefix stored: one decode step resumes
                       generation (longest-prefix match at ``len-1``);
* ``no_reuse``       — recompute-everything baseline (no KV fetch at all).

TTFT = virtual time of KV lookup + block fetch + a modeled per-token step
cost for the tokens actually pushed through decode (`PREFILL_TOK_S`; data
movement is on the sim clock already, model step time is not — the JAX
compute here runs reduced configs whose wall time is meaningless for the
paper-scale ratio).  A `warm_restart` section times the full
scale-down-survivor sequence on a third cluster: params load + hot-KV
preload + first token.  Tokens are asserted identical across every cell.
"""

from __future__ import annotations

import shutil
import tempfile

import numpy as np

from .common import make_cluster, make_fs, save_report

N_PROMPT = 48
BLOCK_TOKENS = 8
EXTEND = 8                 # eval prompt: shared 40-prefix + 8 fresh tokens
MAX_NEW = 4
PREFILL_TOK_S = 2e-3       # modeled decode-step cost (virtual s/token)


def _attach(cl_from, workdir: str, n: int = 4):
    """New cluster over the *same* COS bucket — the scale-to-zero
    survivor's view (cluster caches empty, external storage intact)."""
    cl = make_cluster(workdir, n=n)
    cl.cos = cl_from.cos
    for s in cl.servers.values():
        s.cos = cl_from.cos
    return cl


def _ttft(cl, engine, prompt, label: str, quiet: bool) -> dict:
    t0 = cl.clock.now
    toks, info = engine.generate_with_reuse(prompt, max_new=MAX_NEW,
                                            store=False)
    cl.clock.sleep((info["prefill_steps"] + 1) * PREFILL_TOK_S)
    cell = {"ttft_s": round(cl.clock.now - t0, 6),
            "kv_fetch_bytes": info["kv_read_bytes"],
            "reused_len": info["reused_len"],
            "prefill_steps": info["prefill_steps"],
            "exact_hit": info["exact_hit"], "tokens": toks}
    if not quiet:
        print(f"[kv_reuse] {label:13s} ttft={cell['ttft_s'] * 1e3:8.2f}ms "
              f"reused={info['reused_len']:2d} "
              f"prefill={info['prefill_steps']:2d}")
    return cell


def run(quiet: bool = False) -> dict:
    import jax

    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.checkpoint import CheckpointManager
    from repro.serving import KVCacheStore, ModelStore, ServingEngine

    wds = [tempfile.mkdtemp(prefix=f"bench-kv-{i}-") for i in range(3)]
    try:
        cfg = get_reduced("qwen3-0.6b")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(0), max_seq=64)
        rng = np.random.default_rng(11)
        prompt = rng.integers(0, cfg.vocab, N_PROMPT, dtype=np.int32)
        # eval prompt shares the first 40 tokens (a block boundary), then
        # diverges: every tier cell resumes from the 40-snapshot and
        # prefills the same 8 fresh tokens — only the tier differs
        prompt_eval = np.concatenate(
            [prompt[:N_PROMPT - EXTEND],
             rng.integers(0, cfg.vocab, EXTEND, dtype=np.int32)])

        # ---- publish: serve once, persisting snapshots; drain to COS ----
        cl = make_cluster(wds[0], n=4)
        fs_pub = make_fs(cl, consistency="weak")
        CheckpointManager(fs_pub, "/bench/model").save(0, params,
                                                       durable=True)
        kv_pub = KVCacheStore(fs_pub, "/bench/kv", block_tokens=BLOCK_TOKENS)
        eng_pub = ServingEngine(model, params, max_len=64, kvstore=kv_pub)
        base_toks, _ = eng_pub.generate_with_reuse(prompt, max_new=MAX_NEW)
        # store=False: the eval prompt's own (47-token) prefix must NOT be
        # persisted, or every tier cell would find an exact hit instead of
        # resuming from the shared 40-block
        base_eval, _ = eng_pub.generate_with_reuse(prompt_eval,
                                                   max_new=MAX_NEW,
                                                   store=False)
        cl.drain_dirty()
        kv_stats = dict(kv_pub.stats)

        # ---- tier cells on a scale-to-zero survivor cluster -------------
        cl2 = _attach(cl, wds[1])
        fs_a = make_fs(cl2, consistency="weak")
        t0 = cl2.clock.now
        params_a, params_bytes = ModelStore(fs_a, "/bench/model").load(
            0, like=params)
        params_cold_s = cl2.clock.now - t0
        eng_a = ServingEngine(model, params_a, max_len=64,
                              kvstore=KVCacheStore(fs_a, "/bench/kv",
                                                   block_tokens=BLOCK_TOKENS))
        cells = {"cold_cos": _ttft(cl2, eng_a, prompt_eval, "cold_cos",
                                   quiet)}
        fs_b = make_fs(cl2, consistency="weak", node=cl2.node_list()[1])
        eng_b = ServingEngine(model, params_a, max_len=64,
                              kvstore=KVCacheStore(fs_b, "/bench/kv",
                                                   block_tokens=BLOCK_TOKENS))
        cells["cluster_cache"] = _ttft(cl2, eng_b, prompt_eval,
                                       "cluster_cache", quiet)
        cells["node_cache"] = _ttft(cl2, eng_b, prompt_eval, "node_cache",
                                    quiet)
        # exact-hit premise: the full-prompt prefix is resident node-local
        # (a replica re-serving a prompt it answered before) — warm it once
        # unmeasured, then measure the resume
        eng_b.generate_with_reuse(prompt, max_new=1, store=False)
        cells["exact_hit"] = _ttft(cl2, eng_b, prompt, "exact_hit", quiet)
        eng_none = ServingEngine(model, params_a, max_len=64)
        cells["no_reuse"] = _ttft(cl2, eng_none, prompt_eval, "no_reuse",
                                  quiet)

        # ---- warm restart: params + hot KV + first token, end to end ----
        cl3 = _attach(cl, wds[2])
        fs_c = make_fs(cl3, consistency="weak")
        t0 = cl3.clock.now
        params_c, _ = ModelStore(fs_c, "/bench/model").load(0, like=params)
        t_params = cl3.clock.now - t0
        kv_c = KVCacheStore(fs_c, "/bench/kv", block_tokens=BLOCK_TOKENS)
        hit = kv_c.lookup(prompt, cap=N_PROMPT - 1)
        assert hit is not None
        kv_c.get(hit[1])                       # hot-prefix preload
        t_kv = cl3.clock.now - t0 - t_params
        eng_c = ServingEngine(model, params_c, max_len=64, kvstore=kv_c)
        warm_cell = _ttft(cl3, eng_c, prompt, "warm_restart", quiet)
        warm = {"params_s": round(t_params, 6),
                "params_bytes": params_bytes,
                "kv_preload_s": round(t_kv, 6),
                "kv_preload_bytes": kv_c.stats["get_bytes"],
                "first_token_s": warm_cell["ttft_s"],
                "restart_to_first_token_s": round(
                    t_params + t_kv + warm_cell["ttft_s"], 6)}

        # tokens must be identical everywhere reuse was in play
        for name, cell in cells.items():
            want = base_toks if name == "exact_hit" else base_eval
            assert cell.pop("tokens") == want, f"token mismatch in {name}"
        assert warm_cell["tokens"] == base_toks

        cold = cells["cold_cos"]["ttft_s"]
        rep = {
            "model": "qwen3-0.6b (reduced)", "prompt_len": N_PROMPT,
            "eval_shared_prefix": N_PROMPT - EXTEND,
            "block_tokens": BLOCK_TOKENS, "max_new": MAX_NEW,
            "prefill_tok_s": PREFILL_TOK_S,
            "ttft": cells,
            "warm_restart": warm,
            "kv_store": {"prefixes": kv_stats["puts"],
                         "put_bytes": kv_stats["put_bytes"]},
            "speedup_vs_cold_pct": {
                name: round(100 * (1 - c["ttft_s"] / cold), 1)
                for name, c in cells.items() if name != "cold_cos"},
            "tokens_match": True,
        }
        save_report("kv_reuse", rep)
        if not quiet:
            sp = rep["speedup_vs_cold_pct"]
            print(f"[kv_reuse] exact_hit cuts TTFT {sp['exact_hit']:.1f}% "
                  f"vs cold COS (node {sp['node_cache']:.1f}%, cluster "
                  f"{sp['cluster_cache']:.1f}%)")
        cl3.close()
        cl2.close()
        cl.close()
        return rep
    finally:
        for wd in wds:
            shutil.rmtree(wd, ignore_errors=True)


if __name__ == "__main__":
    run()
