"""Traffic/QoS smoke: two open-loop load points vs a checked-in baseline.

Run by `scripts/check.sh` as a regression gate for the open-loop harness and
the Router's admission control (mirroring rpc_smoke / flush_smoke):

* `low`      — 300 ops/s, no admission, 3 nodes: the healthy-regime p99
  must not regress (this is the raw fabric + strict-client service path);
* `overload` — 1400 ops/s (well past the 3-node knee) *with* the reference
  QoS policy: the contracted gold tenant's p99 must stay bounded and its
  shed rate zero, while the best-effort shed rate must not creep up.

    PYTHONPATH=src python -m benchmarks.traffic_smoke --check
    PYTHONPATH=src python -m benchmarks.traffic_smoke --update-baseline
"""

from __future__ import annotations

import sys

from .common import Gate, gate_main, save_report
from .traffic import run_point

N_NODES = 3
HORIZON_S = 1.0
SEED = 31337
LOW_OPS_S = 300
OVERLOAD_OPS_S = 1200
# 3 nodes (the big sweep's 600 is for 4).  Gold's contracted rate must
# clear its overload-point offer (0.25 * 1200 = 300 ops/s < 0.75 * 500 *
# env_per_op), or the smoke's own policy sheds the class it gates on.
CAPACITY_OPS_S = 500

GATES = [
    Gate("low.p99_ms", tolerance=0.25, slack=2.0),
    Gate("overload.gold_p99_ms", tolerance=0.25, slack=5.0),
    # gold must never be shed: baseline is 0.0, so the gate is pure slack
    Gate("overload.gold_shed_rate", tolerance=0.0, slack=0.005),
    # best-effort shed absorbs the overload; creep means the policy (or the
    # fabric's envelope accounting) changed under it
    Gate("overload.best_shed_rate", tolerance=0.10, slack=0.05),
]


def run(quiet: bool = False) -> dict:
    low = run_point(LOW_OPS_S, admission=False, nodes=N_NODES,
                    horizon_s=HORIZON_S, seed=SEED,
                    capacity_ops_s=CAPACITY_OPS_S, pool_per_tenant=8)
    over = run_point(OVERLOAD_OPS_S, admission=True, nodes=N_NODES,
                     horizon_s=HORIZON_S, seed=SEED,
                     capacity_ops_s=CAPACITY_OPS_S, pool_per_tenant=8)
    rep = {
        "nodes": N_NODES, "horizon_s": HORIZON_S, "seed": SEED,
        "low": {
            "offered_ops_s": LOW_OPS_S,
            "p99_ms": low["overall"]["p99_ms"],
            "p999_ms": low["overall"]["p999_ms"],
            "shed_rate": low["overall"]["shed_rate"],
        },
        "overload": {
            "offered_ops_s": OVERLOAD_OPS_S,
            "gold_p99_ms": over["tenants"]["gold"]["p99_ms"],
            "gold_shed_rate": over["tenants"]["gold"]["shed_rate"],
            "best_shed_rate": over["tenants"]["best"]["shed_rate"],
            "jain_fairness": over["jain_fairness"],
        },
    }
    save_report("traffic_smoke", rep)
    if not quiet:
        print(f"[traffic-smoke] low p99={rep['low']['p99_ms']:.3f}ms; "
              f"overload gold p99={rep['overload']['gold_p99_ms']:.3f}ms "
              f"(shed {rep['overload']['gold_shed_rate']:.1%}), "
              f"best shed {rep['overload']['best_shed_rate']:.0%}")
    return rep


def main() -> int:
    return gate_main("traffic-smoke", run, "traffic_smoke_baseline.json",
                     GATES,
                     baseline_keys=["nodes", "horizon_s",
                                    "low.p99_ms", "overload.gold_p99_ms",
                                    "overload.gold_shed_rate",
                                    "overload.best_shed_rate"])


if __name__ == "__main__":
    sys.exit(main())
