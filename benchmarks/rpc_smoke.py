"""RPC-count smoke: fixed metadata+data workload vs a checked-in baseline.

Run by `scripts/check.sh` as a regression gate for the metadata fast paths:
a deterministic workload (4 dirs x 16 small files on 4 nodes, three
stat/listdir passes, one read-back pass) must not cost more than 20% extra
RPC traffic vs `reports/bench/rpc_smoke_baseline.json` — both wire
envelopes (`Router.rpc_count`, what batching reduces) and typed sub-calls
(summed per-method `rpc_stats()` calls, what leases reduce).

    PYTHONPATH=src python -m benchmarks.rpc_smoke --check
    PYTHONPATH=src python -m benchmarks.rpc_smoke --update-baseline
"""

from __future__ import annotations

import sys

from .common import Gate, bench_env, blob, gate_main, make_fs, save_report

N_NODES = 4
N_DIRS = 4
FILES_PER_DIR = 16
PASSES = 3

GATES = [Gate("rpc_envelopes"), Gate("rpc_subcalls")]


def run(quiet: bool = False) -> dict:
    with bench_env("bench-rpc-smoke-", n=N_NODES) as cl:
        fs = make_fs(cl)
        for d in range(N_DIRS):
            fs.makedirs(f"/bench/d{d}")
            for i in range(FILES_PER_DIR):
                fs.write_file(f"/bench/d{d}/f{i}.bin", blob(8192, d * 64 + i))
        for _ in range(PASSES):
            for d in range(N_DIRS):
                fs.listdir(f"/bench/d{d}")
                for i in range(FILES_PER_DIR):
                    fs.stat(f"/bench/d{d}/f{i}.bin")
        for d in range(N_DIRS):
            for i in range(FILES_PER_DIR):
                fs.read_file(f"/bench/d{d}/f{i}.bin")
        subcalls = sum(v["calls"] for v in cl.rpc_stats().values())
        rep = {
            "nodes": N_NODES, "dirs": N_DIRS, "files": N_DIRS * FILES_PER_DIR,
            "passes": PASSES,
            "rpc_envelopes": cl.router.rpc_count,
            "rpc_subcalls": int(subcalls),
            "batched_subcalls": cl.router.batched_subcalls,
            "lease_hits": sum(fs.client.stats.get(k, 0) for k in
                              ("lease_attr_hits", "lease_lookup_hits",
                               "lease_readdir_hits")),
            "virtual_s": round(cl.clock.now, 6),
        }
    save_report("rpc_smoke", rep)
    if not quiet:
        print(f"[rpc-smoke] {rep['rpc_envelopes']} envelopes / "
              f"{rep['rpc_subcalls']} sub-calls "
              f"({rep['lease_hits']} lease hits) in "
              f"{rep['virtual_s']:.3f} virtual s")
    return rep


def main() -> int:
    return gate_main("rpc-smoke", run, "rpc_smoke_baseline.json", GATES,
                     baseline_keys=["nodes", "files", "passes",
                                    "rpc_envelopes", "rpc_subcalls"])


if __name__ == "__main__":
    sys.exit(main())
