"""RPC-count smoke: fixed metadata+data workload vs a checked-in baseline.

Run by `scripts/check.sh` as a regression gate for the metadata fast paths:
a deterministic workload (4 dirs x 16 small files on 4 nodes, three
stat/listdir passes, one read-back pass) must not cost more than 20% extra
RPC traffic vs `reports/bench/rpc_smoke_baseline.json` — both wire
envelopes (`Router.rpc_count`, what batching reduces) and typed sub-calls
(summed per-method `rpc_stats()` calls, what leases reduce).

    PYTHONPATH=src python -m benchmarks.rpc_smoke --check
    PYTHONPATH=src python -m benchmarks.rpc_smoke --update-baseline
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile

from .common import REPORT_DIR, blob, make_cluster, make_fs, save_report

N_NODES = 4
N_DIRS = 4
FILES_PER_DIR = 16
PASSES = 3
REGRESSION_TOLERANCE = 0.20

BASELINE_PATH = os.path.join(REPORT_DIR, "rpc_smoke_baseline.json")


def run(quiet: bool = False) -> dict:
    wd = tempfile.mkdtemp(prefix="bench-rpc-smoke-")
    cl = make_cluster(wd, n=N_NODES)
    fs = make_fs(cl)
    for d in range(N_DIRS):
        fs.makedirs(f"/bench/d{d}")
        for i in range(FILES_PER_DIR):
            fs.write_file(f"/bench/d{d}/f{i}.bin", blob(8192, d * 64 + i))
    for _ in range(PASSES):
        for d in range(N_DIRS):
            fs.listdir(f"/bench/d{d}")
            for i in range(FILES_PER_DIR):
                fs.stat(f"/bench/d{d}/f{i}.bin")
    for d in range(N_DIRS):
        for i in range(FILES_PER_DIR):
            fs.read_file(f"/bench/d{d}/f{i}.bin")
    subcalls = sum(v["calls"] for v in cl.rpc_stats().values())
    rep = {
        "nodes": N_NODES, "dirs": N_DIRS, "files": N_DIRS * FILES_PER_DIR,
        "passes": PASSES,
        "rpc_envelopes": cl.router.rpc_count,
        "rpc_subcalls": int(subcalls),
        "batched_subcalls": cl.router.batched_subcalls,
        "lease_hits": sum(fs.client.stats.get(k, 0) for k in
                          ("lease_attr_hits", "lease_lookup_hits",
                           "lease_readdir_hits")),
        "virtual_s": round(cl.clock.now, 6),
    }
    cl.close()
    shutil.rmtree(wd, ignore_errors=True)
    save_report("rpc_smoke", rep)
    if not quiet:
        print(f"[rpc-smoke] {rep['rpc_envelopes']} envelopes / "
              f"{rep['rpc_subcalls']} sub-calls "
              f"({rep['lease_hits']} lease hits) in "
              f"{rep['virtual_s']:.3f} virtual s")
    return rep


def check(rep: dict) -> int:
    if not os.path.exists(BASELINE_PATH):
        print(f"[rpc-smoke] no baseline at {BASELINE_PATH}; "
              "run --update-baseline first", file=sys.stderr)
        return 1
    with open(BASELINE_PATH) as f:
        base = json.load(f)
    rc = 0
    for metric in ("rpc_envelopes", "rpc_subcalls"):
        limit = base[metric] * (1.0 + REGRESSION_TOLERANCE)
        if rep[metric] > limit:
            print(f"[rpc-smoke] REGRESSION: {metric} {rep[metric]} > "
                  f"{limit:.0f} (baseline {base[metric]} "
                  f"+{REGRESSION_TOLERANCE:.0%})", file=sys.stderr)
            rc = 1
    if rc == 0:
        print(f"[rpc-smoke] OK: {rep['rpc_envelopes']} envelopes / "
              f"{rep['rpc_subcalls']} sub-calls within "
              f"{REGRESSION_TOLERANCE:.0%} of baseline "
              f"({base['rpc_envelopes']} / {base['rpc_subcalls']})")
    return rc


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if RPC counts regress >20%% vs baseline")
    ap.add_argument("--update-baseline", action="store_true",
                    help="record current RPC counts as the baseline")
    args = ap.parse_args()
    rep = run()
    if args.update_baseline:
        os.makedirs(REPORT_DIR, exist_ok=True)
        with open(BASELINE_PATH, "w") as f:
            json.dump({"nodes": rep["nodes"], "files": rep["files"],
                       "passes": rep["passes"],
                       "rpc_envelopes": rep["rpc_envelopes"],
                       "rpc_subcalls": rep["rpc_subcalls"]}, f, indent=1)
        print(f"[rpc-smoke] baseline updated: {rep['rpc_envelopes']} "
              f"envelopes / {rep['rpc_subcalls']} sub-calls")
        return 0
    if args.check:
        return check(rep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
