"""Multi-tenant metadata benchmark: closed-loop tenants over a shared tree,
before/after the PR 7 metadata fast paths, plus a hot-directory contention
microbenchmark (vote-no vs wait-die lock queueing).

Regenerates `reports/bench/multi_tenant.json` (the in-repo generator went
missing in PR 6; this replaces it).  Three sections:

* `fastpath.off` / `fastpath.on` — the same 8-tenant stat/listdir/read
  workload with leases + same-destination batching disabled vs enabled:
  total RPC envelopes, metadata-op p50/p99 (virtual time), lease hit counts,
  per-tenant fairness.
* `hot_dir.voteno` / `hot_dir.waitdie` — tenants creating files in ONE hot
  directory while younger writers keep grabbing the directory lock.  Under
  vote-no every tenant attempt aborts until the churn stops; under wait-die
  the older tenant queues once, is handed the lock at release, and the
  younger grabbers die instead (ECONFLICT aborts drop per tenant).

    PYTHONPATH=src python -m benchmarks.multi_tenant
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import Errno, FSError
from repro.core.types import Cmd, InodeKind, meta_key

from .common import (bench_env, blob, fastpath_off, make_fs, pctl,
                     rpc_summary, save_report)

N_TENANTS = 8
N_NODES = 4
FILES_PER_TENANT = 6
ROUNDS = 6
SEED = 20260808

HOT_TENANTS = 6
HOT_ROUNDS = 6


def _tenant_workload(mode: str) -> dict:
    """Closed-loop: each round every tenant stats a file, lists its own dir,
    lists the shared dir, and every 3rd round reads one small file."""
    with bench_env(f"bench-mt-{mode}-", n=N_NODES) as cl:
        if mode == "off":
            fastpath_off(cl)
        nodes = cl.node_list()
        tenants = [make_fs(cl, node=nodes[i % len(nodes)])
                   for i in range(N_TENANTS)]
        admin = tenants[0]
        admin.makedirs("/bench/shared")
        admin.write_file("/bench/shared/manifest.bin", blob(4096, 999))
        for i, fs in enumerate(tenants):
            fs.makedirs(f"/bench/t{i}")
            for j in range(FILES_PER_TENANT):
                fs.write_file(f"/bench/t{i}/f{j}.bin", blob(8192, i * 64 + j))
        rng = np.random.default_rng(SEED)
        t_loop0, env0 = cl.clock.now, cl.router.rpc_count
        lat: list[float] = []
        busy = [0.0] * N_TENANTS
        for r in range(ROUNDS):
            for i, fs in enumerate(tenants):
                j = int(rng.integers(FILES_PER_TENANT))
                ops = [lambda: fs.stat(f"/bench/t{i}/f{j}.bin"),
                       lambda: fs.listdir(f"/bench/t{i}"),
                       lambda: fs.listdir("/bench/shared"),
                       lambda: fs.exists("/bench/shared/manifest.bin")]
                if r % 3 == 2:
                    ops.append(lambda: fs.read_file(f"/bench/t{i}/f{j}.bin"))
                for op in ops:
                    t0 = cl.clock.now
                    op()
                    dt = cl.clock.now - t0
                    lat.append(dt)
                    busy[i] += dt
        makespan = cl.clock.now - t_loop0
        lease_hits = sum(fs.client.stats.get(k, 0) for fs in tenants for k in
                         ("lease_attr_hits", "lease_lookup_hits",
                          "lease_readdir_hits"))
        return {
            "tenants": N_TENANTS, "nodes": N_NODES, "rounds": ROUNDS,
            "meta_ops": len(lat),
            "makespan_s": round(makespan, 6),
            "throughput_ops_s": round(len(lat) / max(makespan, 1e-9), 1),
            "meta_p50_ms": round(pctl(lat, 50) * 1e3, 6),
            "meta_p99_ms": round(pctl(lat, 99) * 1e3, 6),
            "rpc_envelopes_total": cl.router.rpc_count,
            "rpc_envelopes_loop": cl.router.rpc_count - env0,
            "batched_subcalls": cl.router.batched_subcalls,
            "lease_hits": lease_hits,
            "fairness_busy_ratio": round(max(busy) / max(min(busy), 1e-9), 3),
            "rpc_methods": rpc_summary(cl),
        }


def _hot_dir_cell(lock_mode: str) -> dict:
    """Older tenants create files in one hot directory while younger
    writers keep taking the directory lock between their attempts."""
    with bench_env(f"bench-hot-{lock_mode}-", n=3) as cl:
        cl.cfg.lock_mode = lock_mode
        fs = make_fs(cl)
        fs.makedirs("/bench/hot")
        hot = fs.resolve("/bench/hot")
        srv = cl.servers[cl.any_server().owner(meta_key(hot))]
        key = meta_key(hot)
        blocker_seq = [9000]      # far younger than any tenant under wait-die

        def grab():
            blocker_seq[0] += 1
            txid_p = {"client_id": 999, "seq": blocker_seq[0], "txseq": 0}
            res, _ = srv.rpc_prepare(cl.clock.now, txid_p=txid_p,
                                     cmd_id=int(Cmd.TX_PREPARE_META), ops=[],
                                     keys=[key])
            return txid_p if res.get("vote") else None

        def drop(txid_p):
            if txid_p is not None:
                srv.rpc_abort(cl.clock.now, txid_p=txid_p)

        t0 = cl.clock.now
        aborts = failures = blocker_holds = blocker_dies = 0
        for i in range(HOT_TENANTS):
            done = False
            for _r in range(HOT_ROUNDS):
                b = grab()                  # churn: a young writer interposes
                if b is not None:
                    blocker_holds += 1
                else:
                    blocker_dies += 1       # wait-die: younger grabber dies
                try:
                    srv.coord_create(cl.clock.now, client_id=50 + i,
                                     seq=i + 1, parent=hot, name=f"t{i}.bin",
                                     kind=int(InodeKind.FILE),
                                     cos_bucket="bench",
                                     cos_key=f"hot/t{i}.bin",
                                     mtime=cl.clock.now)
                    drop(b)
                    done = True
                    break
                except FSError as e:
                    if e.errno != Errno.ECONFLICT:
                        raise
                    aborts += 1
                    cl.clock.sleep(0.0005)  # the client's retry backoff
                    drop(b)                 # churning writer gives up
            if not done:
                try:                        # quiet retry after the churn
                    srv.coord_create(cl.clock.now, client_id=50 + i,
                                     seq=i + 1, parent=hot, name=f"t{i}.bin",
                                     kind=int(InodeKind.FILE),
                                     cos_bucket="bench",
                                     cos_key=f"hot/t{i}.bin",
                                     mtime=cl.clock.now)
                except FSError:
                    failures += 1
        created = len(fs.listdir("/bench/hot"))
        return {
            "lock_mode": lock_mode, "tenants": HOT_TENANTS,
            "churn_rounds": HOT_ROUNDS,
            "econflict_aborts": aborts,
            "aborts_per_tenant": round(aborts / HOT_TENANTS, 2),
            "tenant_failures": failures,
            "created": created,
            "blocker_holds": blocker_holds,
            "blocker_dies": blocker_dies,
            "lock_queued": srv.stats.get("lock_queued", 0),
            "lock_die": srv.stats.get("lock_die", 0),
            "makespan_s": round(cl.clock.now - t0, 6),
        }


def run(quiet: bool = False) -> dict:
    rep: dict = {"seed": SEED}
    fp = {m: _tenant_workload(m) for m in ("off", "on")}
    fp["rpc_reduction_pct"] = round(
        100 * (1 - fp["on"]["rpc_envelopes_total"] /
               max(fp["off"]["rpc_envelopes_total"], 1)), 1)
    fp["meta_p99_reduction_pct"] = round(
        100 * (1 - fp["on"]["meta_p99_ms"] /
               max(fp["off"]["meta_p99_ms"], 1e-9)), 1)
    rep["fastpath"] = fp
    hot = {m: _hot_dir_cell(m) for m in ("voteno", "waitdie")}
    hot["waitdie_fewer_aborts"] = (hot["waitdie"]["econflict_aborts"]
                                   < hot["voteno"]["econflict_aborts"])
    rep["hot_dir"] = hot
    save_report("multi_tenant", rep)
    if not quiet:
        off, on = fp["off"], fp["on"]
        print(f"[multi-tenant] envelopes {off['rpc_envelopes_total']} -> "
              f"{on['rpc_envelopes_total']} (-{fp['rpc_reduction_pct']}%), "
              f"meta p99 {off['meta_p99_ms']:.3f} -> "
              f"{on['meta_p99_ms']:.3f} ms "
              f"(-{fp['meta_p99_reduction_pct']}%), "
              f"lease hits {on['lease_hits']}")
        v, w = hot["voteno"], hot["waitdie"]
        print(f"[hot-dir] ECONFLICT aborts voteno={v['econflict_aborts']} "
              f"waitdie={w['econflict_aborts']} "
              f"(per tenant {v['aborts_per_tenant']} -> "
              f"{w['aborts_per_tenant']}); younger grabbers died: "
              f"{w['blocker_dies']}")
    return rep


if __name__ == "__main__":
    sys.exit(0 if run() else 1)
