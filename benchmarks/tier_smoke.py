"""Tiered-storage smoke: cold/warm/hot read sweep + write-back durability
vs a checked-in virtual-time baseline.

Run by `scripts/check.sh` as a perf regression gate for the pluggable
backend layer (`core/cos.py`) and the tiering policy (`core/tiering.py`):

* a cold/warm/hot sweep over a two-tier (NVMe over S3-like) bucket mount —
  cold reads hit the durable base and promote, warm reads are served from
  the promoted NVMe copies, hot reads are cluster-cache resident;
* a write-back pass: sub-chunk files written through the filesystem land on
  the NVMe tier tier-dirty, then `scale_to_zero` must push every dirty
  byte to the durable base (`tier_dirty_after` is gated at exactly 0).

A >20% virtual-time regression on any sweep point, or any tier-dirty byte
surviving zero-scale, fails the check (exit 1).

    PYTHONPATH=src python -m benchmarks.tier_smoke --check
    PYTHONPATH=src python -m benchmarks.tier_smoke --update-baseline
"""

from __future__ import annotations

import sys

import numpy as np

from repro.core import SimClock

from .common import (Gate, bench_env, blob, gate_main, make_fs, make_tier,
                     save_report, tier_sweep_section)

N_NODES = 4
WB_FILES = 24
WB_DIRS = 4

GATES = [
    Gate("sweep.cold_s"),
    Gate("sweep.warm_s"),
    Gate("sweep.hot_s"),
    Gate("writeback.drain_s"),
    # absolute gate: no tier-dirty byte may survive scale-to-zero
    Gate("writeback.tier_dirty_after", tolerance=0.0),
]


def _writeback_section() -> dict:
    """Sub-chunk files written through the filesystem: the persisting
    transaction takes the PutObject fast path for colocated single-chunk
    inodes, so those puts land on the NVMe tier tier-dirty (write-back);
    multi-owner files take the MPU path straight to the durable base.
    `scale_to_zero` must then demote every tier-dirty byte before the
    cluster disappears."""
    clock = SimClock()
    tier = make_tier(clock, nvme_mb=32)
    with bench_env("bench-tier-wb-", n=N_NODES, chunk=1 << 20,
                   backends={"tiered": tier}, backend="tiered",
                   clock=clock) as cl:
        fs = make_fs(cl)
        rng = np.random.default_rng(7)
        for d in range(WB_DIRS):
            fs.makedirs(f"/bench/d{d}")
        total = 0
        for i in range(WB_FILES):
            sz = int(rng.integers(64, 512)) << 10   # sub-chunk: <= 512 KiB
            total += sz
            fs.write_file(f"/bench/d{i % WB_DIRS}/f{i}.bin", blob(sz, i))
        t0 = cl.clock.now
        cl.drain_dirty(max_rounds=32)
        dirty_after_drain = tier.tier_dirty_bytes()
        cl.scale_to_zero()
        drain_s = cl.clock.now - t0
    stats = tier.stats()
    return {
        "files": WB_FILES, "total_mb": round(total / 1e6, 1),
        "drain_s": round(drain_s, 6),
        "tier_dirty_after_drain": dirty_after_drain,
        "tier_dirty_after": tier.tier_dirty_bytes(),
        "durable_objects": tier.base.object_count("bench"),
        "tier": stats,
    }


def run(quiet: bool = False) -> dict:
    rep = {
        "sweep": tier_sweep_section(n_nodes=N_NODES),
        "writeback": _writeback_section(),
    }
    save_report("tier_smoke", rep)
    if not quiet:
        sw, wb = rep["sweep"], rep["writeback"]
        print(f"[tier-smoke] cold {sw['cold_s']:.3f}s -> warm "
              f"{sw['warm_s']:.3f}s ({sw['warm_speedup']}x) -> hot "
              f"{sw['hot_s']:.3f}s ({sw['hot_speedup']}x) | writeback "
              f"{wb['files']} files drain {wb['drain_s']:.3f}s "
              f"tier-dirty-after {wb['tier_dirty_after']}")
    return rep


def main() -> int:
    return gate_main("tier-smoke", run, "tier_smoke_baseline.json", GATES,
                     baseline_keys=["sweep.cold_s", "sweep.warm_s",
                                    "sweep.hot_s", "writeback.files",
                                    "writeback.drain_s",
                                    "writeback.tier_dirty_after"])


if __name__ == "__main__":
    sys.exit(main())
